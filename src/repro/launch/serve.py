"""Continuous-batching serving driver: Poisson arrivals, chunked prefill,
per-slot sampled decode, streaming per-request output (DESIGN.md §7).
``--paged`` switches the engine to paged KV-cache mode (DESIGN.md §9):
block-granular pool admission, page-table decode, preemption on pool OOM.

    # MoE + dense smoke archs through a mixed-length Poisson trace:
    PYTHONPATH=src python -m repro.launch.serve --smoke --mesh 1x1

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --smoke --slots 4 --requests 8 --prompt-len 64 --gen 32 --mesh 1x2

    # paged smoke with an overcommitted pool (preemption exercised):
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --page-size 16 --pool-pages 12

    # disaggregated prefill/decode smoke (role-split workers, page-id
    # KV handoff, DESIGN.md §10); tight decode pool exercises the
    # preempt -> re-prefill path:
    PYTHONPATH=src python -m repro.launch.serve --smoke --disagg \
        --page-size 16 --pool-pages 12

``--ep-size N`` shards MoE expert weights across N devices of the mesh
``model`` axis for the decode-time expert hop (DESIGN.md §11); dense
archs ignore it. ``--ep-placement planned`` turns on online
heterogeneity-aware re-placement from the observed routing EMA:

    PYTHONPATH=src python -m repro.launch.serve --smoke \
        --arch qwen3-moe-30b-a3b --mesh 1x2 --ep-size 2 \
        --ep-placement planned

``--fleet`` scales disagg to an elastic multi-group fleet (DESIGN.md
§12): N prefill + M decode groups of mixed device classes behind a
router, with heartbeat failure recovery and (``--fleet-elastic``)
role flips. ``--kill-group GID@TICK`` injects a crash mid-trace; the
killed group's in-flight requests re-enter the router and re-prefill
token-exactly:

    PYTHONPATH=src python -m repro.launch.serve --smoke --fleet \
        --prefill-groups a40 --decode-groups v100,v100 \
        --page-size 8 --kill-group 2@8

``--chaos SPEC --chaos-seed N`` (fleet mode only) arms the seeded fault
injector (DESIGN.md §13) with a ``ft.chaos`` schedule — transfer chunk
drop/corrupt/stall, heartbeat loss (zombie + rejoin), mid-tick group
crashes — and ``--slo-ttft S`` turns on SLO-aware shedding. The summary
gains a ``chaos`` section with the replayable event log + signature:

    PYTHONPATH=src python -m repro.launch.serve --smoke --fleet \
        --prefill-groups a40,a40 --decode-groups v100,v100 \
        --page-size 8 --chaos 'drop%0.6*4' --chaos-seed 101

Exit status: non-zero when any request is rejected, dropped, or left
unfinished — the CI serve-smoke, disagg-smoke, ep-smoke, fleet-smoke and
chaos-smoke steps gate on it. An ``--ep-size`` that does not divide the
expert count (or exceed the mesh axis) is REJECTED with a non-zero exit,
never truncated; so is a fleet topology with zero groups of a role or an
unknown device class, a malformed ``--chaos`` spec, ``--chaos`` without
``--fleet``, and (chaos mode) any surviving pool with pages still in use
after the trace drains.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh
from repro.models import registry, stack
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve import (BlockAllocator, ContinuousBatchingEngine, Request,
                         SamplingParams, Scheduler, ServeMetrics,
                         make_continuous_program)

SMOKE_ARCHS = ("qwen3-moe-30b-a3b", "llama3.2-3b")  # MoE + dense


def parse_group_spec(spec: str, default_cls: str) -> list:
    """``--prefill-groups``/``--decode-groups`` value: either an integer
    count (that many groups of the role's default class) or a
    comma-separated device-class list (one group per entry)."""
    items = [x.strip() for x in (spec or "").split(",") if x.strip()]
    if len(items) == 1 and items[0].isdigit():
        return [default_cls] * int(items[0])
    return items


def parse_kills(specs) -> list:
    """``--kill-group GID@TICK`` occurrences -> [(tick, gid)]."""
    kills = []
    for spec in specs or ():
        try:
            gid, tick = spec.split("@")
            kills.append((int(tick), int(gid)))
        except ValueError:
            raise ValueError(
                f"--kill-group wants GID@TICK, got {spec!r}") from None
    return kills


def build_trace(seed: int, n: int, rate: float, prompt_len: int, gen: int,
                vocab: int, sampling: SamplingParams,
                eos_token=None) -> list:
    """Mixed-length Poisson trace: exponential inter-arrivals (in engine
    ticks), prompt lengths in [prompt_len/4, prompt_len], generation
    budgets in [gen/2, gen]."""
    rng = np.random.RandomState(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.randint(max(1, prompt_len // 4), prompt_len + 1))
        gmax = int(rng.randint(max(1, gen // 2), gen + 1))
        prompt = rng.randint(0, vocab, size=(plen,)).astype(int).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gmax,
                            sampling=sampling, eos_token=eos_token,
                            arrival=t))
    return reqs


def serve_arch_lockstep(cfg, mesh, run, args) -> dict:
    """Whole-batch lockstep fallback for enc-dec / vision archs (they need
    per-request front embeddings the continuous engine does not carry)."""
    from repro.models.config import ShapeConfig
    from repro.serve import BatchedServer, make_serve_program
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("cli", "decode", max_len, args.slots)
    program = make_serve_program(cfg, mesh, run, shape, max_len=max_len)
    key = jax.random.PRNGKey(0)
    with mesh:
        params = jax.jit(
            lambda: split_params(stack.init_model(key, cfg))[0],
            out_shardings=program.param_shardings)()
    server = BatchedServer(program, params, args.slots, max_len)
    prompts = jax.random.randint(key, (args.slots, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    fronts = {}
    if cfg.is_encdec:
        fronts["encoder_embeds"] = jnp.zeros(
            (args.slots, cfg.encoder_seq, cfg.d_model),
            run.policy.compute_dtype)
    if cfg.vision_seq > 0:
        fronts["vision_embeds"] = jnp.zeros(
            (args.slots, cfg.vision_seq, cfg.vision_dim or cfg.d_model),
            run.policy.compute_dtype)
    t0 = time.perf_counter()
    server.submit_prefill(prompts, fronts)
    out = [server.tokens]
    for _ in range(args.gen - 1):
        out.append(server.step(fronts))
    toks = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    tps = round(args.slots * args.gen / dt, 2)
    print(f"[serve] arch={cfg.name} lockstep fallback generated "
          f"{toks.shape} in {dt:.2f}s ({tps} tok/s)")
    return {"tokens_per_s": tps, "lockstep": True,
            "ok": toks.shape == (args.slots, args.gen)}


def serve_arch(arch: str, args) -> dict:
    cfg = registry.get_config(arch)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    if cfg.is_encdec or cfg.vision_seq > 0:
        return serve_arch_lockstep(cfg, mesh, run, args)
    max_len = args.prompt_len + args.gen
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    trace = build_trace(args.seed, args.requests, args.rate,
                        args.prompt_len, args.gen, cfg.vocab_size, sampling)
    metrics = ServeMetrics()
    stream = None
    if args.stream:
        def stream(rid, tok, fin):
            print(f"[{cfg.name}] rid={rid} tok={tok}"
                  + (" <done>" if fin else ""))

    key = jax.random.PRNGKey(0)
    chaos = None
    shed: set = set()
    leaked: list = []
    ep = None
    if getattr(args, "ep_size", 0):
        if cfg.is_moe:
            from repro.serve.ep_decode import (EPDecodeConfig,
                                               validate_ep_config)
            planned = args.ep_placement == "planned"
            ep = EPDecodeConfig(ep_size=args.ep_size, n_chunks=2,
                                rebalance_every=8 if planned else 0,
                                drift_threshold=0.05)
            try:
                validate_ep_config(cfg, mesh, ep)
            except ValueError as e:
                # Rejected, never truncated: a non-dividing --ep-size (or
                # a mesh without the EP axis) fails the run outright.
                print(f"[serve] FAIL arch={cfg.name}: bad EP config: {e}",
                      file=sys.stderr)
                return {"ok": False, "n_requests": 0,
                        "ep_error": str(e)}
        else:
            print(f"[serve] arch={cfg.name} is dense; --ep-size ignored")

    if getattr(args, "fleet", False):
        # Elastic multi-group fleet (DESIGN.md §12): N prefill + M decode
        # groups of mixed device classes, router placement, optional role
        # flips, heartbeat failure recovery. --kill-group injects faults.
        from repro.serve.fleet import make_fleet
        try:
            pre_cls = parse_group_spec(args.prefill_groups, "a40")
            dec_cls = parse_group_spec(args.decode_groups, "v100")
            kills = parse_kills(args.kill_group)
            if getattr(args, "chaos", None):
                # Malformed specs are rejected here (ValueError -> FAIL,
                # non-zero exit) — never a silently-ignored fault plan.
                from repro.ft.chaos import FaultInjector, FaultPlan
                chaos = FaultInjector(FaultPlan.parse(args.chaos),
                                      seed=args.chaos_seed)
            params = split_params(stack.init_model(key, cfg))[0]
            engine = make_fleet(
                cfg, mesh, run, params, prefill_classes=pre_cls,
                decode_classes=dec_cls, decode_slots=args.slots,
                max_len=max_len, page_size=args.page_size,
                decode_pages=args.pool_pages,
                prefill_pages=args.prefill_pool_pages,
                prefill_chunk=args.prefill_chunk,
                token_budget=args.prefill_budget, seed=args.seed,
                metrics=metrics, on_token=stream,
                elastic=args.fleet_elastic, chaos=chaos,
                slo_ttft=getattr(args, "slo_ttft", None))
        except ValueError as e:
            # Invalid topology (zero groups of a role, unknown device
            # class, malformed kill or chaos spec): non-zero exit.
            print(f"[serve] FAIL arch={cfg.name}: bad fleet config: {e}",
                  file=sys.stderr)
            return {"ok": False, "n_requests": 0, "fleet_error": str(e)}
        t0 = time.perf_counter()
        try:
            results = engine.run(trace, kills=kills)
        except RuntimeError as e:
            # Wedged fleet (e.g. the only decode group was killed without
            # --fleet-elastic): requests would be dropped — fail the run.
            print(f"[serve] FAIL arch={cfg.name}: fleet stalled: {e}",
                  file=sys.stderr)
            return {"ok": False, "n_requests": 0, "fleet_error": str(e)}
        dt = time.perf_counter() - t0
        shed = set(engine.shed)
    elif getattr(args, "disagg", False):
        # Disaggregated prefill/decode deployment (DESIGN.md §10): the
        # decode pool takes --pool-pages, the prefill pool
        # --prefill-pool-pages; KV crosses between them as pages.
        from repro.serve.disagg import make_disagg
        params = split_params(stack.init_model(key, cfg))[0]
        engine = make_disagg(
            cfg, mesh, run, params, decode_slots=args.slots,
            max_len=max_len, page_size=args.page_size,
            decode_pages=args.pool_pages,
            prefill_pages=args.prefill_pool_pages,
            prefill_chunk=args.prefill_chunk,
            token_budget=args.prefill_budget, seed=args.seed,
            metrics=metrics, on_token=stream, ep=ep)
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0
    else:
        paged_kw = {}
        if args.paged:
            paged_kw = dict(page_size=args.page_size,
                            n_pages=args.pool_pages)
        program = make_continuous_program(cfg, mesh, run, n_slots=args.slots,
                                          max_len=max_len, seed=args.seed,
                                          ep=ep, **paged_kw)
        allocator = None
        if args.paged:
            allocator = BlockAllocator(program.n_pages, program.page_size,
                                       program.max_pages)
        sched = Scheduler(args.slots, max_len,
                          prefill_chunk=args.prefill_chunk,
                          token_budget=args.prefill_budget,
                          allocator=allocator)
        if ep is not None:
            # The EP engine places (permutes + shards) the replicated
            # init params itself, so no out_shardings jit here.
            from repro.serve.ep_decode import EPContinuousBatchingEngine
            params = split_params(stack.init_model(key, cfg))[0]
            engine = EPContinuousBatchingEngine(program, params, sched,
                                                metrics=metrics,
                                                on_token=stream)
        else:
            with mesh:
                params = jax.jit(
                    lambda: split_params(stack.init_model(key, cfg))[0],
                    out_shardings=program.param_shardings)()
            engine = ContinuousBatchingEngine(program, params, sched,
                                              metrics=metrics,
                                              on_token=stream)
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0

    for req in trace:
        if req.rid in shed:  # explicit SLO-shed outcome (chaos/slo mode)
            print(f"[{cfg.name}] rid={req.rid} prompt={len(req.prompt)} "
                  f"SHED")
            continue
        tr = metrics.requests.get(req.rid)
        if tr is None:  # rejected at submit — never entered the engine
            print(f"[{cfg.name}] rid={req.rid} prompt={len(req.prompt)} "
                  f"REJECTED")
            continue
        toks = results[req.rid]
        print(f"[{cfg.name}] rid={req.rid} prompt={len(req.prompt)} "
              f"gen={len(toks)}/{req.max_new_tokens} "
              f"first_tick={tr.first_token_tick} "
              f"finish_tick={tr.finish_tick} out={toks[:8]}...")
    s = metrics.summary()
    print(f"[serve] arch={cfg.name} {s['n_requests']} requests, "
          f"{s['n_generated_tokens']} tokens in {dt:.2f}s "
          f"({s['tokens_per_s']} tok/s, ttft p50 {s['ttft_s']['p50']:.3f}s, "
          f"itl p50 {s['itl_s']['p50']:.4f}s, "
          f"queue depth max {s['queue_depth']['max']}, "
          f"max concurrent {s['max_concurrent_active']})")
    if getattr(args, "fleet", False):
        # Surviving pools must hold the exactly-once page invariant even
        # after kills, recoveries, and role flips.
        for g in engine.groups:
            g.worker.allocator.check()
        if chaos is not None:
            # Chaos acceptance: a drained fleet must hold ZERO pages on
            # every surviving pool — a leftover page is a leak the fault
            # path failed to roll back.
            leaked = [g.gid for g in engine.groups
                      if g.worker.allocator.pages_in_use != 0]
        st = engine.transfer.stats
        s["fleet"] = {
            "elastic": bool(args.fleet_elastic),
            "ticks": engine.tick_count,
            "groups": [{"gid": g.gid, "cls": g.cls, "role": g.role,
                        "flips": g.flips} for g in engine.groups],
            "events": [{"tick": e.tick, "kind": e.kind, "gid": e.gid,
                        "detail": e.detail} for e in engine.events],
            "n_flips": engine.n_flips,
            "n_killed": len([e for e in engine.events
                             if e.kind == "dead"]),
            "kv_transfers": st.n_transfers,
            "kv_pages_shipped": st.n_pages,
        }
        if chaos is not None:
            s["chaos"] = {
                "spec": args.chaos,
                "seed": args.chaos_seed,
                "events": chaos.log(),
                "signature": chaos.log_signature(),
                "counters": metrics.robust.as_dict(),
                "n_shed": len(shed),
                "leaked_groups": leaked,
            }
            print(f"[serve] arch={cfg.name} chaos: spec={args.chaos!r} "
                  f"seed={args.chaos_seed} faults={len(chaos.log())} "
                  f"sig={chaos.log_signature()} shed={len(shed)} "
                  f"retries={st.n_retries} aborts={st.n_aborts} "
                  f"fenced={metrics.robust.fenced_stale_completions}")
        roles = ",".join(f"g{g.gid}={g.cls}:{g.role}"
                         for g in engine.groups)
        print(f"[serve] arch={cfg.name} fleet: {roles} "
              f"flips={engine.n_flips} "
              f"events={len(engine.events)} transfers={st.n_transfers} "
              f"ttft_p99={s['ttft_s']['p99']:.3f}s "
              f"itl_p99={s['itl_s']['p99']:.4f}s")
    elif getattr(args, "disagg", False):
        st = engine.transfer.stats
        s["disagg"] = {
            "page_size": args.page_size,
            "decode_pages": engine.decode.allocator.n_pages,
            "prefill_pages": engine.prefill.allocator.n_pages,
            "decode_page_peak": engine.decode.page_peak,
            "n_preempted": engine.decode.sched.n_preempted,
            "kv_transfers": st.n_transfers,
            "kv_pages_shipped": st.n_pages,
            "kv_bytes_shipped": st.bytes,
        }
        print(f"[serve] arch={cfg.name} disagg: page_size={args.page_size} "
              f"transfers={st.n_transfers} pages={st.n_pages} "
              f"preempted={engine.decode.sched.n_preempted}")
        engine.prefill.allocator.check()
        engine.decode.allocator.check()
    elif args.paged:
        s["paged"] = eng_occ = engine.page_occupancy()
        print(f"[serve] arch={cfg.name} paged: page_size={args.page_size} "
              f"pool={program.n_pages} peak={eng_occ['page_peak']} "
              f"preempted={eng_occ['n_preempted']}")
    if ep is not None and not getattr(args, "disagg", False) \
            and not getattr(args, "fleet", False):
        s["ep"] = {
            "ep_size": ep.ep_size,
            "placement_mode": args.ep_placement,
            "n_rebalances": engine.n_rebalances,
            "ema_updates": engine.ema.n_updates,
        }
        print(f"[serve] arch={cfg.name} ep: ep_size={ep.ep_size} "
              f"placement={args.ep_placement} "
              f"rebalances={engine.n_rebalances} "
              f"ema_updates={engine.ema.n_updates}")
    # Gate: every traced request must finish with its full token budget
    # spent (traces carry no EOS) and nothing may be rejected or dropped.
    # Rejected rids never reach metrics (submit raises before on_submit);
    # they count as unfinished here AND appear in engine.rejected. Shed
    # requests (SLO admission, chaos mode) are an EXPLICIT outcome: they
    # are excluded from the finish requirement, and in chaos mode the run
    # additionally fails when any surviving pool leaked pages.
    unfinished = [r.rid for r in trace
                  if r.rid not in shed
                  and (metrics.requests.get(r.rid) is None
                       or metrics.requests[r.rid].finish_tick is None
                       or len(results.get(r.rid, [])) != r.max_new_tokens)]
    s["ok"] = not engine.rejected and not unfinished and not leaked \
        and s["n_requests"] == len(trace) - len(shed)
    if not s["ok"]:
        print(f"[serve] FAIL arch={cfg.name}: rejected={engine.rejected} "
              f"unfinished={unfinished} leaked={leaked} "
              f"finished={s['n_requests']}"
              f"/{len(trace) - len(shed)}", file=sys.stderr)
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: llama3.2-3b; with --smoke and no --arch, "
                         "runs the MoE + dense smoke pair")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent KV slots (decode batch)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--rate", type=float, default=0.4,
                    help="Poisson arrival rate (requests per engine tick)")
    ap.add_argument("--prompt-len", type=int, default=48,
                    help="max prompt length (trace mixes lengths below it)")
    ap.add_argument("--gen", type=int, default=24,
                    help="max new tokens (trace mixes budgets below it)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prefill tokens per tick (default: one chunk)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (block allocator + page-table "
                         "decode, DESIGN.md §9)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache lines per page (paged mode)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pool size in pages (default: full "
                         "reservation capacity; smaller values overcommit "
                         "and exercise preemption)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode deployment "
                         "(DESIGN.md §10): role-split workers over "
                         "separate paged pools, KV handed off as pages; "
                         "--pool-pages sizes the decode pool")
    ap.add_argument("--prefill-pool-pages", type=int, default=None,
                    help="prefill-side pool size in pages (disagg mode; "
                         "default: two max-length sequences)")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic multi-group fleet (DESIGN.md §12): "
                         "N prefill + M decode groups of mixed device "
                         "classes behind a router, heartbeat failure "
                         "recovery; see --prefill-groups/--decode-groups")
    ap.add_argument("--prefill-groups", default="a40",
                    help="fleet prefill groups: an integer count or a "
                         "comma-separated device-class list, e.g. "
                         "'a40,a40' or '2' (default one a40 group)")
    ap.add_argument("--decode-groups", default="v100",
                    help="fleet decode groups: an integer count or a "
                         "comma-separated device-class list, e.g. "
                         "'v100,v100' (default one v100 group)")
    ap.add_argument("--fleet-elastic", action="store_true",
                    help="enable elastic role reassignment: idle groups "
                         "flip prefill<->decode when the bottleneck "
                         "role shifts or a role dies out")
    ap.add_argument("--kill-group", action="append", metavar="GID@TICK",
                    help="fault injection (repeatable): crash fleet group "
                         "GID at the start of tick TICK")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="seeded fault schedule (fleet mode, DESIGN.md "
                         "§13): ';'-joined ft.chaos entries "
                         "SITE[@TICK][:TARGET][%%PROB][*COUNT][~DURATION] "
                         "— e.g. 'drop%%0.6*4;hb_loss@6:g3~8'; malformed "
                         "specs exit non-zero")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos injector: the same "
                         "(seed, spec) replays the identical fault log")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="SLO-aware admission (fleet mode): shed arrivals "
                         "whose best prefill ETA exceeds this many "
                         "seconds of estimated work")
    ap.add_argument("--ep-size", type=int, default=0,
                    help="shard MoE expert weights across this many "
                         "devices of the mesh 'model' axis for decode "
                         "(DESIGN.md §11); must divide the expert count — "
                         "rejected otherwise, never truncated; 0 = off")
    ap.add_argument("--ep-placement", choices=("uniform", "planned"),
                    default="uniform",
                    help="uniform: static round-robin expert placement; "
                         "planned: online heterogeneity-aware re-placement "
                         "from the observed routing EMA")
    args = ap.parse_args(argv)

    if args.chaos and not args.fleet:
        print("[serve] --chaos requires --fleet (the chaos hook points "
              "live in the fleet controller)", file=sys.stderr)
        return 1
    archs = [args.arch] if args.arch else \
        (list(SMOKE_ARCHS) if args.smoke else ["llama3.2-3b"])
    failed = []
    for arch in archs:
        s = serve_arch(arch, args)
        if not s.get("ok", True):
            failed.append(arch)
    if failed:
        print(f"[serve] FAILED archs: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
