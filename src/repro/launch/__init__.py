"""Launch entry points: mesh construction, dry-run, train and serve CLIs."""
