"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
FUNCTIONS so the dry-run controls XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis is the
    DCN boundary (pure DP; experts stay within a pod, DESIGN.md §3.1)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh helper (tests / small runs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
