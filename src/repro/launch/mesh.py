"""Production mesh construction.

Importing this module never touches jax device state; meshes are built by
FUNCTIONS so the dry-run controls XLA_FLAGS before first jax init.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on jax >= 0.5 (explicit-sharding work).

    On older jax (0.4.x, this container's pin) ``jax.make_mesh`` has no such
    parameter and every axis already behaves like ``Auto``, so a plain Mesh
    is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis is the
    DCN boundary (pure DP; experts stay within a pod, DESIGN.md §3.1)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh helper (tests / small runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_mesh_kwargs(len(axes)))
