"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but not collective
traffic; we parse the (per-device, post-partitioning) HLO text and sum the
operand sizes of every collective op, bucketed by kind.

Compiled HLO prints operands as %names (untyped), so per-op operand bytes
are recovered from the RESULT shape + the replica-group size:
    all-gather:      operand = result / group_size
    reduce-scatter:  operand = result * group_size
    all-reduce / all-to-all / collective-permute: operand = result
Async pairs (-start/-done) are counted once via the -start op, whose tuple
result's first element is the operand.

NOTE (cost-analysis caveat, see launch/dryrun.py): XLA's HloCostAnalysis
counts while-loop bodies ONCE, so FLOPs/bytes of scanned layer stacks are
under-counted; the dry-run measures an unrolled 1-repeat and 2-repeat
variant and extrapolates linearly — exact, since every repeat lowers to the
same body.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.core import hardware as HW

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit list form {{0,1,2,3},{...}} -> size of first group
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective traffic by op kind.

    Two aggregates:
      total      — sum of operand sizes (the brief's metric).
      ring_total — ring-algorithm wire bytes per device:
                   all-reduce 2·X·(g-1)/g, all-gather/reduce-scatter
                   X·(g-1)/g on the FULL tensor X, all-to-all X·(g-1)/g,
                   collective-permute X.
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    ring = {k: 0.0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if re.search(r"(all-gather|all-reduce|all-to-all|reduce-scatter|"
                     r"collective-permute)-done\(", line):
            continue
        kind = m.group(2)
        result_part = m.group(1)
        shapes = _SHAPE_RE.findall(result_part)
        if not shapes:
            continue
        g = _group_size(line)
        if m.group(3):  # async -start: tuple (operand, result, ...)
            op_bytes = _shape_bytes(*shapes[0])
            full = op_bytes * g if kind == "all-gather" else op_bytes
        else:
            res_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
            if kind == "all-gather":
                op_bytes = res_bytes // g
                full = res_bytes
            elif kind == "reduce-scatter":
                op_bytes = res_bytes * g
                full = op_bytes
            else:
                op_bytes = res_bytes
                full = res_bytes
        out[kind] += op_bytes
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            ring[kind] += 2.0 * full * frac
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            ring[kind] += full * frac
        else:  # collective-permute
            ring[kind] += full
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    out["ring_total"] = int(sum(ring[k] for k in COLLECTIVE_OPS))
    return out


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled (arch x shape x mesh) cell."""

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops: float  # 6*N_active*D analytical

    peak_flops: float = HW.ROOFLINE_PEAK_FLOPS
    hbm_bw: float = HW.ROOFLINE_HBM_BW
    ici_bw: float = HW.ROOFLINE_ICI_BW
    ici_links: int = 3  # v5e 2D torus: ~3 usable link-pairs per chip

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / (self.ici_bw *
                                                   self.ici_links)

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap model: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat / capacity-padding / dispatch waste)."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline lower bound."""
        denom = (self.step_time_lower_bound * self.n_devices
                 * self.peak_flops)
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "useful_flops_frac": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }
