"""End-to-end training driver.

Runs real training on whatever devices exist (CPU-scale smoke through
full-pod) with checkpointing, resume, fault-tolerance hooks and zebra
parallelism for MoE archs.

    PYTHONPATH=src python -m repro.launch.train --arch mixtral-d2 \
        --steps 50 --batch 8 --seq 256 --mesh 1x2 --smoke

--smoke uses the reduced same-family config (registry.smoke_config) so a
~CPU-sized model trains a few hundred steps; omit it to use the full config
(real hardware).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.zebra_spmd import ZebraConfig
from repro.data import DataConfig, DataLoader
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.train import optimizer as opt
from repro.train.step import make_train_program


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-d2")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--zebra", action="store_true", default=True)
    ap.add_argument("--no-zebra", dest="zebra", action="store_false")
    ap.add_argument("--zebra-mode", default="replicated")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--n-chunks", type=int, default=1,
                    help="capacity chunks for overlapped dispatch "
                         "(alltoall mode, DESIGN.md §8)")
    ap.add_argument("--offload-experts", type=int, default=0,
                    help="experts kept replicated attention-side "
                         "(alltoall mode Asym-EA offload)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default=None, help="token .bin (else synthetic)")
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="chunked", moe_impl="gather",
                    remat="full")
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    zcfg = None
    if args.zebra and cfg.is_moe:
        zcfg = ZebraConfig(mode=args.zebra_mode,
                           num_microbatches=args.microbatches,
                           n_chunks=args.n_chunks,
                           offload_experts=args.offload_experts)
    opt_cfg = opt.OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    program = make_train_program(cfg, mesh, run, shape, opt_cfg=opt_cfg,
                                 zcfg=zcfg)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, path=args.data)
    loader = DataLoader(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    with mesh:
        params = program.init_params(seed=0)
        opt_state = program.init_opt(params)
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step, params, opt_state, extra = ckpt.restore(
            jax.tree.map(lambda x: x, params), opt_state,
            shardings=program.param_shardings,
            opt_shardings=program.opt_shardings)
        loader.load_state_dict(extra.get("loader", {"step": start_step}))
        print(f"[train] resumed from step {start_step}")
    loader.step = max(loader.step, start_step)

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"zebra={dataclasses.asdict(program.zcfg) if program.zcfg else None}")

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(loader)
        # modality-frontend stubs
        extra_in = {}
        if cfg.is_encdec:
            extra_in["encoder_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                run.policy.compute_dtype)
        if cfg.vision_seq > 0:
            extra_in["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_seq, cfg.vision_dim or cfg.d_model),
                run.policy.compute_dtype)
        with mesh:
            params, opt_state, metrics = program.train_step(
                params, opt_state, {**batch, **extra_in})
        if (step + 1) % args.log_every == 0 or step == start_step:
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            print(f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f} ms/step",
                  flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state,
                      extra={"loader": loader.state_dict()}, blocking=False)
    if ckpt:
        ckpt.save(args.steps, params, opt_state,
                  extra={"loader": loader.state_dict()})
        ckpt.wait()
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
