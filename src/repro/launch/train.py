"""End-to-end training driver.

Runs real training on whatever devices exist (CPU-scale smoke through
full-pod) with checkpointing, resume, fault-tolerance hooks and zebra
parallelism for MoE archs.

    PYTHONPATH=src python -m repro.launch.train --arch mixtral-d2 \
        --steps 50 --batch 8 --seq 256 --mesh 1x2 --smoke

--smoke uses the reduced same-family config (registry.smoke_config) so a
~CPU-sized model trains a few hundred steps; omit it to use the full config
(real hardware).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.zebra_spmd import ZebraConfig
from repro.obs import format_report, write_chrome_trace
from repro.obs import trace as obs_trace
from repro.data import DataConfig, DataLoader
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.train import optimizer as opt
from repro.train.step import make_train_program


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-d2")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--zebra", action="store_true", default=True)
    ap.add_argument("--no-zebra", dest="zebra", action="store_false")
    ap.add_argument("--zebra-mode", default="replicated")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--n-chunks", type=int, default=1,
                    help="capacity chunks for overlapped dispatch "
                         "(alltoall mode, DESIGN.md §8)")
    ap.add_argument("--offload-experts", type=int, default=0,
                    help="experts kept replicated attention-side "
                         "(alltoall mode Asym-EA offload)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data", default=None, help="token .bin (else synthetic)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run "
                         "(obs §15; one tick per training step)")
    ap.add_argument("--trace-wall", action="store_true",
                    help="trace with wall-clock timestamps instead of the "
                         "deterministic step clock")
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="chunked", moe_impl="gather",
                    remat="full")
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    zcfg = None
    if args.zebra and cfg.is_moe:
        zcfg = ZebraConfig(mode=args.zebra_mode,
                           num_microbatches=args.microbatches,
                           n_chunks=args.n_chunks,
                           offload_experts=args.offload_experts)
    opt_cfg = opt.OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    program = make_train_program(cfg, mesh, run, shape, opt_cfg=opt_cfg,
                                 zcfg=zcfg)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, path=args.data)
    loader = DataLoader(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    with mesh:
        params = program.init_params(seed=0)
        opt_state = program.init_opt(params)
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start_step, params, opt_state, extra = ckpt.restore(
            jax.tree.map(lambda x: x, params), opt_state,
            shardings=program.param_shardings,
            opt_shardings=program.opt_shardings)
        loader.load_state_dict(extra.get("loader", {"step": start_step}))
        print(f"[train] resumed from step {start_step}")
    loader.step = max(loader.step, start_step)

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"zebra={dataclasses.asdict(program.zcfg) if program.zcfg else None}")

    tracer = None
    last_logged: dict = {}
    if args.trace_out:
        tracer = obs_trace.Tracer(wall=bool(args.trace_wall))
        obs_trace.install(tracer)
        tracer.declare_track("train", pid="train")
        tracer.registry.register("train", lambda: dict(last_logged))

    t0 = time.time()
    for step in range(start_step, args.steps):
        if tracer is not None:
            tracer.advance(step)
        batch = next(loader)
        # modality-frontend stubs
        extra_in = {}
        if cfg.is_encdec:
            extra_in["encoder_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                run.policy.compute_dtype)
        if cfg.vision_seq > 0:
            extra_in["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_seq, cfg.vision_dim or cfg.d_model),
                run.policy.compute_dtype)
        with mesh, obs_trace.TRACER.span("train", f"step {step}", step=step):
            params, opt_state, metrics = program.train_step(
                params, opt_state, {**batch, **extra_in})
        if (step + 1) % args.log_every == 0 or step == start_step:
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            print(f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f} ms/step",
                  flush=True)
            if tracer is not None:
                last_logged.update(step=step + 1,
                                   loss=float(metrics["loss"]),
                                   nll=float(metrics["nll"]),
                                   ms_per_step=round(dt * 1e3, 1))
                tracer.count("train", "loss", float(metrics["loss"]))
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state,
                      extra={"loader": loader.state_dict()}, blocking=False)
    if ckpt:
        ckpt.save(args.steps, params, opt_state,
                  extra={"loader": loader.state_dict()})
        ckpt.wait()
    if tracer is not None:
        if program.zcfg is not None:
            _lay_zebra_sim(tracer, cfg, args)
        obj = write_chrome_trace(tracer, args.trace_out)
        obs_trace.install(None)
        print(f"[train] trace: {len(obj['traceEvents'])} events "
              f"-> {args.trace_out}")
        for line in format_report(obj["reproIdle"]).splitlines():
            print(f"[train] idle: {line}")
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")
    return 0


def _lay_zebra_sim(tracer, cfg, args) -> None:
    """Lay the analytic zebra timeline (core.simulator over the canonical
    schedule, reference A40/V100 ZP pair) onto seconds-domain tracks next
    to the measured step clock. The zebra SPMD overlap itself is scheduled
    inside XLA, so this simulated view — the paper's own validation
    instrument — is what carries the per-stream / a2a-exposed breakdown."""
    from repro.core import hardware as HW
    from repro.core import schedule as S
    from repro.core.profiler import ZPGroupShape, profile_layer
    from repro.core.simulator import CommTimes, simulate
    from repro.obs.zebra import sim_to_trace

    zp = ZPGroupShape(M=1, N=1, attn_class=HW.A40, exp_class=HW.V100)
    link_bw = min(zp.attn_class.link_bw, zp.exp_class.link_bw)
    times = profile_layer(cfg, zp, args.batch, args.seq, args.microbatches,
                          link_bw=link_bw)
    sched = S.canonical_schedule(cfg.n_layers, args.microbatches,
                                 n_chunks=max(args.n_chunks, 1))
    res = simulate(sched, times, CommTimes(times.t_dispatch, times.t_combine),
                   cfg.n_experts, zp.N, zp.M)
    sim_to_trace(sched, res, tracer)
    print(f"[train] zebra-sim: iter={res.iter_time * 1e3:.2f} ms "
          f"attn_util={res.attn_util:.2f} exp_util={res.exp_util:.2f}")


if __name__ == "__main__":
    sys.exit(main())
