import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: jit lowering
with ShapeDtypeStruct stand-ins, SPMD partitioning over the production mesh
(16x16 single pod / 2x16x16 multi-pod), compiled memory analysis (fits?),
cost analysis (FLOPs/bytes) and collective-traffic parsing for the roofline
(EXPERIMENTS.md §Dry-run / §Roofline).

Cost methodology: XLA's HloCostAnalysis counts while-loop bodies ONCE, so a
scanned L-layer stack under-reports FLOPs/bytes/collectives. Each cell is
therefore compiled twice more with the layer stack UNROLLED at 1 and 2
pattern-repeats; per-repeat costs are the difference (exact — every repeat
lowers to the same HLO) and totals are extrapolated to the full depth. The
memory analysis and the compile-must-succeed proof always come from the
full scanned production program.

Usage:
    python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun.csv
"""

import argparse  # noqa: E402
import csv  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.inputs import input_specs  # noqa: E402
from repro.core.zebra_spmd import ZebraConfig  # noqa: E402
from repro.launch.hlo_analysis import Roofline, collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.models.modules import Policy, RunConfig  # noqa: E402
from repro.serve.engine import make_serve_program  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.step import make_train_program  # noqa: E402


def model_flops(cfg, shape) -> float:
    """Analytical 'useful' FLOPs per step: 6*N_active*tokens (train),
    2*N_active*tokens (prefill/decode)."""
    n = cfg.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * shape.tokens


def _compile_variant(cfg, shape, mesh, run, zcfg, constrain_grads=False):
    """Lower + compile one config variant; returns (compiled, lower_s,
    compile_s)."""
    t0 = time.time()
    if shape.kind == "train":
        z = zcfg if cfg.is_moe else None
        program = make_train_program(cfg, mesh, run, shape, zcfg=z,
                                     donate=True,
                                     constrain_grads=constrain_grads)
        import functools
        oshapes = jax.eval_shape(
            functools.partial(opt.init_opt_state,
                              master_weights=program.master_weights),
            program.param_shapes)
        batch = input_specs(cfg, shape)
        lowered = program.train_step.lower(program.param_shapes, oshapes,
                                           batch)
    else:
        sp = make_serve_program(cfg, mesh, run, shape)
        specs = input_specs(cfg, shape)
        fronts = {k: v for k, v in specs.items() if k != "tokens"}
        from repro.train.step import abstract_params
        pshapes, _ = abstract_params(cfg)
        if shape.kind == "prefill":
            lowered = sp.prefill_step.lower(pshapes, sp.state_shapes,
                                            specs["tokens"], fronts)
        else:
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = sp.decode_step.lower(pshapes, sp.state_shapes,
                                           specs["tokens"], idx, fronts)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0


def _costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def _unrolled_variant(cfg, repeats: int):
    P = len(cfg.pattern)
    tail = len(cfg.tail_specs)
    return dataclasses.replace(cfg, n_layers=repeats * P + tail, unroll=True)


def measured_costs(cfg, shape, mesh, run, zcfg, constrain_grads=False):
    """Exact per-device costs via 1/2-repeat unrolled extrapolation."""
    reps_full = cfg.n_pattern_repeats
    if reps_full <= 2:
        c = _costs(_compile_variant(dataclasses.replace(cfg, unroll=True),
                                    shape, mesh, run, zcfg,
                                    constrain_grads)[0])
        return c
    c1 = _costs(_compile_variant(_unrolled_variant(cfg, 1), shape, mesh,
                                 run, zcfg, constrain_grads)[0])
    c2 = _costs(_compile_variant(_unrolled_variant(cfg, 2), shape, mesh,
                                 run, zcfg, constrain_grads)[0])

    def extrap(a, b):
        return a + max(b - a, 0.0) * (reps_full - 1)

    coll_keys = set(c1["coll"]) | set(c2["coll"])
    return {
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes": extrap(c1["bytes"], c2["bytes"]),
        "coll": {k: extrap(c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0))
                 for k in coll_keys},
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               zebra_mode: str = "alltoall", microbatches: int = 4,
               remat: str = "full", costs: bool = True, zcfg=None,
               param_dtype="float32", chunk_q: int = 512,
               constrain_grads: bool = False, embed_mode: str = "sharded",
               capacity_factor: float = 1.25):
    """Lower + compile one cell; returns the full record dict."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped",
                "reason": "full attention at 524k is O(s^2) - per brief"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    run = RunConfig(policy=Policy(param_dtype=jnp.dtype(param_dtype)),
                    attn_impl="chunked", moe_impl="gather",
                    remat=remat, chunk_q=chunk_q, embed_mode=embed_mode)
    zcfg = zcfg or ZebraConfig(mode=zebra_mode, num_microbatches=microbatches,
                               capacity_factor=capacity_factor)

    compiled, t_lower, t_compile = _compile_variant(cfg, shape, mesh, run,
                                                    zcfg, constrain_grads)
    mem = compiled.memory_analysis()
    c = measured_costs(cfg, shape, mesh, run, zcfg, constrain_grads) \
        if costs else _costs(compiled)

    n_dev = mesh.devices.size
    rf = Roofline(
        flops_per_device=c["flops"],
        hbm_bytes_per_device=c["bytes"],
        collective_bytes_per_device=c["coll"]["total"],
        n_devices=n_dev,
        model_flops=model_flops(cfg, shape),
    )
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": n_dev,
        "flops_per_device": c["flops"],
        "hbm_bytes_per_device": c["bytes"],
        "collective_bytes_per_device": c["coll"]["total"],
        "ring_collective_bytes_per_device": c["coll"].get("ring_total", 0.0),
        "t_collective_ring_s": round(c["coll"].get("ring_total", 0.0)
                                     / (50e9 * 3), 6),
        "coll_breakdown": {k: int(v) for k, v in c["coll"].items()
                           if k not in ("total", "ring_total") and v},
        "arg_bytes_per_device": int(mem.argument_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "total_bytes_per_device": int(per_dev_bytes),
        "fits_16gb": bool(mem.temp_size_in_bytes
                          + mem.argument_size_in_bytes < 16e9),
        "model_flops": model_flops(cfg, shape),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in rf.row().items()},
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zebra-mode", default="alltoall",
                    choices=["alltoall", "replicated"])
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the unrolled cost extrapolation compiles")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                try:
                    rec = lower_cell(arch, shape, multi_pod=multi,
                                     zebra_mode=args.zebra_mode,
                                     microbatches=args.microbatches,
                                     remat=args.remat,
                                     costs=not args.no_costs)
                except Exception as e:  # a failure here is a system bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": f"FAIL: {type(e).__name__}: {e}"}
                rec["wall_s"] = round(time.time() - t0, 1)
                records.append(rec)
                print(json.dumps(rec), flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        keys = sorted({k for r in records for k in r})
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            for r in records:
                w.writerow({k: (json.dumps(v) if isinstance(v, dict) else v)
                            for k, v in r.items()})
    n_ok = sum(r.get("status") == "ok" for r in records)
    n_skip = sum(r.get("status") == "skipped" for r in records)
    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} "
          f"failed={len(records) - n_ok - n_skip}", file=sys.stderr)
    return 0 if n_ok + n_skip == len(records) else 1


if __name__ == "__main__":
    sys.exit(main())
