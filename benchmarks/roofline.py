"""Roofline benchmark: three-term roofline per (arch x shape x mesh) cell.

Reads the dry-run grid CSV (experiments/dryrun_single.csv /
dryrun_multi.csv) produced by ``python -m repro.launch.dryrun --all``; if
missing, computes a small representative subset inline (slow). Hardware
constants per the brief: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

import csv
import json
import os

from benchmarks.common import emit

CSVS = ["experiments/dryrun_grid.csv", "experiments/dryrun_single.csv",
        "experiments/dryrun_multi.csv"]
INLINE_CELLS = [("llama3.2-3b", "train_4k"), ("qwen3-moe-30b-a3b",
                                              "train_4k")]


def _emit_row(r):
    if r.get("status") != "ok":
        return
    name = f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
    t = max(float(r["t_compute_s"]), float(r["t_memory_s"]),
            float(r["t_collective_s"]))
    emit(name, t * 1e6,
         f"bound={r['bound']};tc={float(r['t_compute_s']):.3f}s;"
         f"tm={float(r['t_memory_s']):.3f}s;"
         f"tx={float(r['t_collective_s']):.3f}s;"
         f"mfu_bound={float(r['mfu_bound']):.3f};"
         f"useful={float(r['useful_flops_frac']):.3f}")


def main():
    found = False
    for path in CSVS:
        if not os.path.exists(path):
            continue
        found = True
        with open(path) as f:
            for r in csv.DictReader(f):
                _emit_row(r)
    if not found:
        print("# no dry-run CSV found; computing a small inline subset "
              "(run `python -m repro.launch.dryrun --all --mesh both` "
              "for the full grid)")
        import subprocess
        import sys
        for arch, shape in INLINE_CELLS:
            out = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                 arch, "--shape", shape, "--mesh", "single"],
                capture_output=True, text=True,
                env={**os.environ, "PYTHONPATH": "src"})
            for line in out.stdout.splitlines():
                if line.startswith("{"):
                    _emit_row(json.loads(line))


if __name__ == "__main__":
    main()
