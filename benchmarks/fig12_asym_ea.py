"""Paper Fig. 12: speed-up from Asym-EA (vs zebra parallelism without it),
Mixtral-W1 and D1 on the O1 setup across sequence lengths."""

from benchmarks.common import SETUPS, emit, global_batch_for
from repro.core.planner import plan_zp_group
from repro.models import registry


def main():
    zp = SETUPS["O1"]
    for model in ("mixtral-w1", "mixtral-d1"):
        cfg = registry.get_config(model)
        for s in (4096, 8192, 16384, 24576, 32768):
            gb = global_batch_for(s)
            plan = plan_zp_group(cfg, zp, gb, s, n_chunks=1)  # paper-faithful: serialized dispatch
            speed = plan.predicted_no_asym.iter_time / \
                plan.predicted.iter_time
            emit(f"fig12/{model}/s{s}", plan.predicted.iter_time * 1e6,
                 f"asym_speedup={speed:.3f}x;offload={sum(plan.offload)}")


if __name__ == "__main__":
    main()
