"""Paper Fig. 11: HeterMoE on 2xA40+2xV100 vs homogeneous EP on 4xA40,
4xV100 and 2xA100."""

import dataclasses

from benchmarks.common import emit, global_batch_for
from repro.core import hardware as HW, simulator as sim
from repro.core.planner import plan_zp_group
from repro.core.profiler import ZPGroupShape
from repro.models import registry


def main():
    cfg = dataclasses.replace(registry.get_config("mixtral-d1"), n_experts=8)
    for s in (4096, 8192, 12288, 16384, 20480, 24576, 32768):
        gb = global_batch_for(s)
        zp = ZPGroupShape(M=2, N=2, attn_class=HW.A40, exp_class=HW.V100)
        plan = plan_zp_group(cfg, zp, gb, s, n_chunks=1)  # paper-faithful: serialized dispatch
        th_hm = gb * s / plan.predicted.iter_time
        emit(f"fig11/s{s}/hetermoe_2a40_2v100",
             plan.predicted.iter_time * 1e6, f"tok_s={th_hm:.0f}")
        for dev, count, tag in [(HW.A40, 4, "4xa40"), (HW.V100, 4, "4xv100"),
                                (HW.A100, 2, "2xa100")]:
            t = sim.homogeneous_ep_iter_time(cfg, dev, count, gb, s)
            emit(f"fig11/s{s}/ep_{tag}", t * 1e6,
                 f"tok_s={gb * s / t:.0f};"
                 f"rel_to_hm={(gb * s / t) / th_hm:.2f}x")


if __name__ == "__main__":
    main()
