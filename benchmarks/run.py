"""Benchmark driver: one module per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig7 fig12 # subset
"""

import sys

from benchmarks import (fig2_component_speedup, fig7_throughput_onprem,
                        fig8_throughput_aws, fig9_pp_comparison,
                        fig10_gpu_ratios, fig11_homogeneous, fig12_asym_ea,
                        roofline, table3_utilization)

BENCHES = {
    "fig2": fig2_component_speedup.main,
    "fig7": fig7_throughput_onprem.main,
    "fig8": fig8_throughput_aws.main,
    "fig9": fig9_pp_comparison.main,
    "fig10": fig10_gpu_ratios.main,
    "fig11": fig11_homogeneous.main,
    "fig12": fig12_asym_ea.main,
    "table3": table3_utilization.main,
    "roofline": roofline.main,
}


def main() -> None:
    which = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        BENCHES[name]()


if __name__ == "__main__":
    main()
