"""Serving benchmark: a fixed mixed-length Poisson trace through the
continuous-batching engine. Tracks tokens/s, time-to-first-token and
inter-token latency across PRs via BENCH_serve.json.

Reuses launch/serve.py::serve_arch (one engine wiring, two entry points)
so the benchmark always measures exactly what the driver runs.

No hard gate: absolute numbers are host-dependent; the JSON is the
trend record (and the run doubles as an integration check — it fails if
any request is dropped or the engine stalls).

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

ARCHS = ("qwen3-moe-30b-a3b", "llama3.2-3b")  # MoE + dense


def bench_arch(arch: str, args) -> dict:
    from repro.launch.serve import serve_arch

    t0 = time.perf_counter()
    s = serve_arch(arch, args)
    wall = time.perf_counter() - t0
    assert s["n_requests"] == args.requests, "dropped requests"
    return {
        "requests": s["n_requests"],
        "generated_tokens": s["n_generated_tokens"],
        "wall_s": round(wall, 3),
        "tokens_per_s": s["tokens_per_s"],
        "ttft_s_p50": round(s["ttft_s"]["p50"], 4),
        "ttft_s_max": round(s["ttft_s"]["max"], 4),
        "itl_s_p50": round(s["itl_s"]["p50"], 5),
        "itl_s_p95": round(s["itl_s"]["p95"], 5),
        "queue_depth_max": s["queue_depth"]["max"],
        "max_concurrent_active": s["max_concurrent_active"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    # fixed-trace knobs serve_arch reads beyond the CLI ones above
    args.mesh = "1x1"
    args.rate = 0.5
    args.seed = 0
    args.prefill_budget = None
    args.temperature = 0.0
    args.top_k = 0
    args.top_p = 1.0
    args.stream = False

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "trace": {"slots": args.slots, "requests": args.requests,
                  "prompt_len": args.prompt_len, "gen": args.gen,
                  "prefill_chunk": args.prefill_chunk, "rate": args.rate,
                  "seed": args.seed},
        "results": {arch: bench_arch(arch, args) for arch in ARCHS},
    }
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
