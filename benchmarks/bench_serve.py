"""Serving benchmark: a fixed mixed-length Poisson trace through the
continuous-batching engine. Tracks tokens/s, time-to-first-token and
inter-token latency across PRs via BENCH_serve.json.

``--disagg`` adds the disaggregation section (DESIGN.md §10): the gate
metric ``disagg.goodput_ratio_sim`` is the SIMULATED goodput of the
role-split deployment over the unified lockstep engine on the same fixed
Poisson trace at an A40+V100 speed ratio — the planner sweeps the
prefill:decode device split and the simulator replays the trace through
both shapes. The unified baseline keeps BOTH devices' HBM worth of decode
slots (2x the disagg decode pool), so the ratio under-counts rather than
flatters disaggregation. A real tiny-engine disagg run rides along as the
measured/informational row.

Reuses launch/serve.py::serve_arch (one engine wiring, two entry points)
so the benchmark always measures exactly what the driver runs.

``--paged`` additionally sweeps the paged-KV engine (DESIGN.md §9) over
page_size in {16, 32, 64} x paged slot counts at a FIXED simulated HBM
budget — the cache lines the reservation engine would pin for ``--slots``
slots (slots x max_len). The pool gets floor(budget / page_size) physical
pages, the engine gets more decode slots than the reservation engine could
back, and ``slots_at_fixed_hbm`` records how many requests it actually
sustained concurrently. ``slot_ratio_best`` (vs the reservation engine's
slot count) is the SIMULATED gate metric — it is a deterministic function
of the trace and scheduler, independent of host speed — and must stay
>= 1.5 (benchmarks/check_regression.py enforces the trend). Throughput
stays measured/informational.

``--ep`` adds the expert-parallel decode section (DESIGN.md §11): the
gate metric ``ep.placement_ratio_sim`` is the SIMULATED trace makespan of
round-robin expert placement over the heterogeneity-aware planned
placement on a fixed Zipf-routed Poisson trace at an A40+V100 decode
group, and the ``hbm`` row records the per-device expert-weight residency
reduction (>= ep_size by construction — the shard is an exact partition).

``--fleet`` adds the elastic fleet section (DESIGN.md §12): the gate
metric ``fleet.goodput_ratio_sim`` is the SIMULATED goodput-under-SLO of
the elastic fleet over the BEST static prefill:decode role split of the
same 2xA40 + 2xV100 groups on a fixed diurnal trace whose bottleneck
role shifts — and must stay >= 1.2. The measured row runs a real tiny
fleet with a decode group killed mid-trace, gating zero-loss recovery.

``--chaos`` adds the chaos-resilience section (DESIGN.md §13): the same
tiny fleet and trace run twice — fault-free, then under the "standard"
combined fault schedule from :func:`repro.core.simulator.chaos_matrix`
(drops + corruption + a stall + a heartbeat-loss zombie window) — and the
gate metric ``chaos.goodput_degraded_ratio`` is the ratio of goodput in
simulated ticks (generated tokens per fleet tick) degraded over clean.
Both runs must finish every request token-exactly (serve_arch gates
this), so the ratio isolates the RECOVERY overhead: retries, re-prefill
after aborted transfers and zombie fencing stretch the tick count but
may not drop work. Deterministic by construction (seeded fault plan,
tick-domain metric), so check_regression.py can gate its trend. The
degraded run's robustness counters ride along in the section.

``--prefix`` adds the prefix-cache section (DESIGN.md §14): the same
shared-prefix multi-tenant trace through the paged engine with the
prefix cache OFF then ON (both via ``build_deployment``). The gate
metric ``prefix.pages_alloc_ratio`` (pages drawn off vs on, must stay
>= 1.3) and ``prefix.tokens_skipped`` are deterministic; the run also
asserts both engines produced IDENTICAL tokens. ``ttft_hit_reduction``
is wall-clock and informational.

``--obs-overhead`` adds the tracing-overhead section (DESIGN.md §15.2):
the same seeded trace through the same deployment with tracing OFF then
ON. Token- and tick-exactness are ASSERTED (the tracer may only observe);
``obs.overhead_ratio`` (traced vs untraced ticks/s, best of three) is
wall-clock and informational — logged against §15.2's soft <5% budget,
never regression-gated.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--paged] \
        [--disagg] [--ep] [--fleet] [--chaos] [--prefix] \
        [--obs-overhead] [--out PATH]
"""

from __future__ import annotations

import argparse
import copy
import json
import pathlib
import time

import jax

ARCHS = ("qwen3-moe-30b-a3b", "llama3.2-3b")  # MoE + dense
PAGED_ARCH = "llama3.2-3b"  # sweep arch (dense decode = fastest runner)
PAGE_SIZES = (16, 32, 64)


def bench_arch(arch: str, args) -> dict:
    from repro.launch.serve import serve_arch

    t0 = time.perf_counter()
    s = serve_arch(arch, args)
    wall = time.perf_counter() - t0
    assert s["n_requests"] == args.requests, "dropped requests"
    assert s.get("ok", True), f"serve gate failed for {arch}"
    out = {
        "requests": s["n_requests"],
        "generated_tokens": s["n_generated_tokens"],
        "wall_s": round(wall, 3),
        "tokens_per_s": s["tokens_per_s"],
        "ttft_s_p50": round(s["ttft_s"]["p50"], 4),
        "ttft_s_p95": round(s["ttft_s"]["p95"], 4),
        "ttft_s_p99": round(s["ttft_s"]["p99"], 4),
        "ttft_s_max": round(s["ttft_s"]["max"], 4),
        "itl_s_p50": round(s["itl_s"]["p50"], 5),
        "itl_s_p95": round(s["itl_s"]["p95"], 5),
        "itl_s_p99": round(s["itl_s"]["p99"], 5),
        "queue_depth_max": s["queue_depth"]["max"],
        "max_concurrent_active": s["max_concurrent_active"],
    }
    if "paged" in s:
        out["paged"] = s["paged"]
    if "disagg" in s:
        out["disagg"] = s["disagg"]
    if "ep" in s:
        out["ep"] = s["ep"]
    if "fleet" in s:
        out["fleet"] = s["fleet"]
    if "chaos" in s:
        out["chaos"] = s["chaos"]
    return out


def bench_paged_sweep(args) -> dict:
    """page_size x slot-count sweep at fixed simulated HBM (see module
    docstring). Returns the BENCH_serve.json ``paged`` section."""
    max_len = args.prompt_len + args.gen
    slots_ref = args.slots  # reservation engine slots at this HBM budget
    budget_lines = slots_ref * max_len
    points = []
    for page_size in PAGE_SIZES:
        pool_pages = budget_lines // page_size
        for mult in (2, 3):
            a = copy.copy(args)
            a.paged = True
            a.page_size = page_size
            a.pool_pages = pool_pages
            a.slots = slots_ref * mult
            a.requests = args.paged_requests
            a.rate = args.paged_rate
            try:
                s = bench_arch(PAGED_ARCH, a)
            except AssertionError as e:  # pool too tight for the trace
                points.append({"page_size": page_size,
                               "pool_pages": pool_pages,
                               "n_slots": a.slots, "error": str(e)})
                continue
            points.append({
                "page_size": page_size,
                "pool_pages": pool_pages,
                "pool_lines": pool_pages * page_size,
                "n_slots": a.slots,
                "slots_at_fixed_hbm": s["max_concurrent_active"],
                "slot_ratio": round(s["max_concurrent_active"] / slots_ref,
                                    3),
                "tokens_per_s": s["tokens_per_s"],
                "page_peak": s["paged"]["page_peak"],
                "mean_lines_per_active_slot":
                    s["paged"]["mean_lines_per_active_slot"],
                "n_preempted": s["paged"]["n_preempted"],
            })
    ok = [p for p in points if "error" not in p]
    assert ok, f"no paged sweep point completed; per-point errors: {points}"
    best = max(ok, key=lambda p: p["slot_ratio"])
    section = {
        "arch": PAGED_ARCH,
        "slots_ref": slots_ref,
        "budget_lines": budget_lines,
        "paged_requests": args.paged_requests,
        "paged_rate": args.paged_rate,
        "points": points,
        "slot_ratio_best": best["slot_ratio"],
        "best_config": {k: best[k] for k in ("page_size", "n_slots")},
    }
    assert best["slot_ratio"] >= 1.5, \
        f"paged engine sustained only {best['slot_ratio']}x the " \
        f"reservation slots at equal HBM (need >= 1.5x)"
    return section


def bench_disagg(args) -> dict:
    """BENCH_serve.json ``disagg`` section (see module docstring)."""
    import numpy as np
    from repro.core import planner
    from repro.core import simulator as sim
    from repro.core.hardware import A40, V100
    from repro.core.profiler import ZPGroupShape
    from repro.models import registry

    # -- simulated gate: fixed mixed Poisson load, A40 (attn) + V100 (exp)
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    rng = np.random.RandomState(0)
    t, trace = 0.0, []
    for _ in range(40):
        t += float(rng.exponential(0.25))
        trace.append(sim.ServeRequest(arrival=t,
                                      prompt=int(rng.randint(512, 4096)),
                                      gen=int(rng.randint(64, 256))))
    zp = ZPGroupShape(M=1, N=1, attn_class=A40, exp_class=V100)
    plan = planner.plan_disagg_group(cfg, zp, trace, prefill_chunk=256,
                                     ctx=2048, slots_per_device=8)
    section = {
        "sim": {
            "arch": cfg.name,
            "classes": [zp.attn_class.name, zp.exp_class.name],
            "n_requests": len(trace),
            "split": {"prefill_attn": plan.prefill_attn,
                      "prefill_exp": plan.prefill_exp,
                      "decode_attn": plan.decode_attn,
                      "decode_exp": plan.decode_exp},
            "goodput_disagg": round(plan.predicted.goodput, 2),
            "goodput_unified": round(plan.predicted_unified.goodput, 2),
            "ttft_p50_disagg_s": round(plan.predicted.ttft_p50, 3),
            "ttft_p50_unified_s": round(plan.predicted_unified.ttft_p50, 3),
            "ttft_ratio": round(plan.ttft_ratio, 3),
        },
        "goodput_ratio_sim": round(plan.goodput_ratio, 3),
    }
    assert plan.goodput_ratio >= 1.2, \
        f"disagg goodput only {plan.goodput_ratio:.2f}x unified " \
        f"(need >= 1.2x at the A40+V100 speed ratio)"

    # -- measured (informational): the real role-split engine end to end
    a = copy.copy(args)
    a.disagg = True
    a.paged = False
    s = bench_arch(PAGED_ARCH, a)
    section["measured"] = {
        "arch": PAGED_ARCH,
        "tokens_per_s": s["tokens_per_s"],
        "ttft_s_p50": s["ttft_s_p50"],
        "kv_transfers": s["disagg"]["kv_transfers"],
        "kv_pages_shipped": s["disagg"]["kv_pages_shipped"],
        "kv_bytes_shipped": s["disagg"]["kv_bytes_shipped"],
    }
    return section


def bench_ep(args) -> dict:
    """BENCH_serve.json ``ep`` section (DESIGN.md §11): the gate metric
    ``ep.placement_ratio_sim`` is the SIMULATED trace makespan of
    round-robin expert placement over the heterogeneity-aware planned
    placement (>1: hot-expert-to-fast-HBM won) on a fixed Zipf-routed
    Poisson trace at an A40+V100 decode group; the HBM row records the
    per-device expert-weight residency EP sharding buys back. A real
    EP-sharded tiny-engine run rides along as measured/informational when
    the host exposes enough devices."""
    from repro.core import planner
    from repro.core import simulator as sim
    from repro.core.hardware import A40, V100
    from repro.models import registry
    from repro.serve.ep_decode import ep_hbm_budget

    cfg = registry.get_config("qwen3-moe-30b-a3b")
    shard_classes = (A40, V100)  # weak-HBM + strong-HBM decode pair
    reqs, hist = sim.zipf_poisson_trace(
        0, 40, 2.0, 256, 128, cfg.n_experts, zipf_s=1.4)
    plan = planner.plan_ep_decode_group(
        cfg, shard_classes, hist, reqs, decode_batch=8, ctx=1024,
        n_chunks=2, link_bw=min(c.link_bw for c in shard_classes))
    # Pool-page accounting at an 80GB-class decode host: the A40/V100
    # classes above price SPEED; neither holds this model's 58GB expert
    # stack replicated, which is exactly why EP sharding exists.
    hbm = ep_hbm_budget(cfg, hbm_bytes=80e9, ep_size=plan.ep_size,
                        page_size=16)
    section = {
        "sim": {
            "arch": cfg.name,
            "classes": [c.name for c in shard_classes],
            "n_requests": len(reqs),
            "zipf_s": 1.4,
            "hist_top4": [round(x, 4) for x in
                          sorted(plan.hist, reverse=True)[:4]],
            # Full placements are E-long lists; record where the four
            # hottest experts landed (shard index) instead.
            "hot_expert_shard_planned": {
                str(e): next(j for j, s in enumerate(plan.placement)
                             if e in s)
                for e in sorted(range(cfg.n_experts),
                                key=lambda e: -plan.hist[e])[:4]},
            "t_step_planned_s": round(plan.t_step_planned, 6),
            "t_step_uniform_s": round(plan.t_step_uniform, 6),
            "step_ratio": round(plan.placement_ratio, 4),
            "makespan_planned_s": round(plan.predicted.makespan, 3),
            "makespan_uniform_s": round(plan.predicted_uniform.makespan, 3),
        },
        "hbm": {
            "expert_bytes_total": hbm["expert_bytes_total"],
            "expert_bytes_per_device": hbm["expert_bytes_per_device"],
            "hbm_reduction": round(hbm["hbm_reduction"], 3),
            "pool_pages_replicated": hbm["pool_pages_replicated"],
            "pool_pages_ep": hbm["pool_pages_ep"],
        },
        "placement_ratio_sim": round(plan.placement_ratio_sim, 4),
    }
    assert plan.placement_ratio_sim > 1.0, \
        f"planned placement did not beat round-robin " \
        f"({plan.placement_ratio_sim:.4f}x on the Zipf trace)"
    assert hbm["hbm_reduction"] >= plan.ep_size, \
        f"EP sharding cut expert residency only " \
        f"{hbm['hbm_reduction']:.2f}x (need >= ep_size={plan.ep_size}x)"

    # -- measured (informational): the real EP-sharded engine end to end
    if jax.device_count() >= 2:
        a = copy.copy(args)
        a.mesh = "1x2"
        a.ep_size = 2
        a.ep_placement = "planned"
        s = bench_arch("qwen3-moe-30b-a3b", a)
        section["measured"] = {
            "arch": "qwen3-moe-30b-a3b",
            "tokens_per_s": s["tokens_per_s"],
            "ttft_s_p50": s["ttft_s_p50"],
            "n_rebalances": s["ep"]["n_rebalances"],
            "ema_updates": s["ep"]["ema_updates"],
        }
    else:
        section["measured"] = {"skipped": "needs >= 2 devices"}
    return section


def bench_fleet(args) -> dict:
    """BENCH_serve.json ``fleet`` section (DESIGN.md §12): the gate
    metric ``fleet.goodput_ratio_sim`` is the SIMULATED
    goodput-under-SLO of the elastic fleet over the BEST static
    prefill:decode role split of the same four groups (2xA40 + 2xV100)
    on a fixed diurnal production trace whose bottleneck role shifts
    between an interactive (decode-bound) peak and a batch
    (prefill-bound) trough — the planner sweeps every static split, so
    the baseline is as strong as a static answer can be. A real tiny
    fleet run with a mid-trace decode-group kill rides along as the
    measured/informational row and doubles as the zero-loss recovery
    check (driver exits non-zero on any dropped request)."""
    from repro.core import planner
    from repro.core import simulator as sim
    from repro.core.hardware import A40, V100

    from repro.models import registry
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    trace = sim.production_trace(
        0, 3000, base_rate=26.0, diurnal_amp=0.5, period_s=90.0,
        prompt_med=1650, prompt_sigma=0.9, gen_med=64, gen_sigma=0.8,
        interactive_frac_amp=0.45, prompt_cap=8192, gen_cap=1024)
    plan = planner.plan_fleet(
        cfg, (A40, A40, V100, V100), trace, prefill_chunk=256, ctx=2048,
        decode_slots=8, page_size=16, slo_ttft=2.0, slo_itl=1.0)
    st, el = plan.predicted_static, plan.predicted_elastic
    section = {
        "sim": {
            "arch": cfg.name,
            "classes": list(plan.classes),
            "n_requests": len(trace),
            "slo_ttft_s": plan.slo_ttft,
            "slo_itl_s": plan.slo_itl,
            "best_static_roles": list(plan.roles),
            "goodput_under_slo_static": round(st.goodput_under_slo, 2),
            "goodput_under_slo_elastic": round(el.goodput_under_slo, 2),
            "good_requests_static": st.n_good,
            "good_requests_elastic": el.n_good,
            "ttft_p99_static_s": round(st.ttft_p99, 3),
            "ttft_p99_elastic_s": round(el.ttft_p99, 3),
            "n_flips_elastic": el.n_flips,
        },
        "goodput_ratio_sim": round(plan.goodput_ratio_sim, 3),
    }
    assert el.n_flips > 0, "elastic fleet sim never flipped a role"
    assert plan.goodput_ratio_sim >= 1.2, \
        f"elastic fleet goodput only {plan.goodput_ratio_sim:.2f}x the " \
        f"best static split (need >= 1.2x on the diurnal trace)"

    # -- measured (informational + zero-loss recovery): real tiny fleet,
    #    one decode group killed mid-trace; serve_arch gates on every
    #    request finishing with its full token budget.
    a = copy.copy(args)
    a.fleet = True
    a.disagg = False
    a.paged = False
    a.prefill_groups = "a40"
    a.decode_groups = "v100,v100"
    a.fleet_elastic = False
    a.kill_group = ["2@8"]
    a.page_size = 8
    s = bench_arch(PAGED_ARCH, a)
    fl = s["fleet"]
    assert fl["n_killed"] == 1, "kill injection did not land"
    section["measured"] = {
        "arch": PAGED_ARCH,
        "groups": fl["groups"],
        "killed_group": 2,
        "events": fl["events"],
        "tokens_per_s": s["tokens_per_s"],
        "ttft_s_p50": s["ttft_s_p50"],
        "kv_transfers": fl["kv_transfers"],
        "kv_pages_shipped": fl["kv_pages_shipped"],
    }
    return section


def bench_chaos(args) -> dict:
    """BENCH_serve.json ``chaos`` section (DESIGN.md §13): the gate
    metric ``chaos.goodput_degraded_ratio`` compares the same tiny
    fleet + trace fault-free vs under the "standard" combined schedule
    from :func:`repro.core.simulator.chaos_matrix`. Goodput is counted
    in SIMULATED ticks (tokens per fleet tick), so the ratio is a
    deterministic function of the seeded fault plan and the scheduler —
    independent of host speed — and serve_arch's own gate guarantees
    both runs finished every request token-exactly before the ratio is
    even computed."""
    from repro.core.simulator import chaos_matrix

    name, spec, seed = next(e for e in chaos_matrix()
                            if e[0] == "standard")
    base = copy.copy(args)
    base.fleet = True
    base.disagg = False
    base.paged = False
    base.prefill_groups = "a40,a40"
    base.decode_groups = "v100,v100"
    base.fleet_elastic = False
    base.kill_group = None
    base.page_size = 8
    base.requests = 5
    base.prompt_len = 32
    base.gen = 12
    base.slo_ttft = None
    base.chaos = None
    base.chaos_seed = 0
    clean = bench_arch(PAGED_ARCH, base)

    a = copy.copy(base)
    a.chaos = spec
    a.chaos_seed = seed
    degraded = bench_arch(PAGED_ARCH, a)

    def goodput(s):
        return s["generated_tokens"] / max(s["fleet"]["ticks"], 1)

    ratio = round(goodput(degraded) / goodput(clean), 4)
    section = {
        "arch": PAGED_ARCH,
        "schedule": name,
        "spec": spec,
        "seed": seed,
        "clean": {
            "ticks": clean["fleet"]["ticks"],
            "generated_tokens": clean["generated_tokens"],
            "goodput_tok_per_tick": round(goodput(clean), 4),
        },
        "degraded": {
            "ticks": degraded["fleet"]["ticks"],
            "generated_tokens": degraded["generated_tokens"],
            "goodput_tok_per_tick": round(goodput(degraded), 4),
            "faults_fired": len(degraded["chaos"]["events"]),
            "signature": degraded["chaos"]["signature"],
            "robustness": degraded["chaos"]["counters"],
        },
        "goodput_degraded_ratio": ratio,
    }
    assert len(degraded["chaos"]["events"]) > 0, \
        "the standard schedule fired no faults — the gate measures nothing"
    assert 0.0 < ratio <= 1.0, \
        f"degraded/clean goodput ratio {ratio} out of range — " \
        f"faults cannot speed the fleet up on a deterministic trace"
    return section


def bench_prefix(args) -> dict:
    """BENCH_serve.json ``prefix`` section (DESIGN.md §14): the same
    shared-prefix multi-tenant trace through the paged engine with the
    prefix cache OFF then ON, both built through
    :func:`repro.serve.build_deployment` (the one construction path).
    The gate metric ``prefix.pages_alloc_ratio`` is the ratio of
    physical pages drawn from the free list — a deterministic function
    of the trace and scheduler, so check_regression.py gates its trend
    and the run itself gates the >= 1.3x floor. Both runs must produce
    IDENTICAL tokens (the cache may only skip work, never change it);
    ``ttft_hit_reduction`` (wall-clock) rides along informationally."""
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import build_tenant_trace
    from repro.models import registry
    from repro.models.modules import Policy, RunConfig
    from repro.serve import (PagedCfg, PrefixCacheCfg, ServeConfig,
                             ServeMetrics, build_deployment)

    a = copy.copy(args)
    a.requests = args.prefix_requests
    a.rate = 0.6
    a.tenants = 3
    a.prompt_len = 48
    a.gen = 12
    a.shared_prefix_len = None  # half the prompt
    cfg = registry.get_config(PAGED_ARCH)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")

    def one(prefix_on):
        sc = ServeConfig(
            slots=args.slots, max_len=a.prompt_len + a.gen,
            prefill_chunk=args.prefill_chunk,
            paged=PagedCfg(enabled=True, page_size=8),
            prefix=PrefixCacheCfg(enabled=prefix_on, fair=prefix_on))
        metrics = ServeMetrics()
        engine = build_deployment(cfg, mesh, run, sc, metrics=metrics)
        trace = build_tenant_trace(a, cfg.vocab_size, sc.sampling)
        t0 = time.perf_counter()
        results = engine.run(trace)
        wall = time.perf_counter() - t0
        assert not engine.rejected and len(results) == len(trace)
        engine.sched.allocator.check()
        if engine.sched.prefix_index is not None:
            engine.sched.prefix_index.check()
        return results, metrics.summary(), engine.page_occupancy(), wall

    res_off, sum_off, occ_off, wall_off = one(False)
    res_on, sum_on, occ_on, wall_on = one(True)
    assert res_on == res_off, \
        "prefix cache changed tokens — it may only skip work"
    pages_ratio = round(occ_off["pages_allocated"]
                        / max(occ_on["pages_allocated"], 1), 3)
    ttft_reduction = round(sum_off["ttft_s"]["p50"]
                           / max(sum_on["ttft_s"]["p50"], 1e-9), 3)
    section = {
        "arch": PAGED_ARCH,
        "trace": {"requests": a.requests, "tenants": a.tenants,
                  "prompt_len": a.prompt_len, "gen": a.gen,
                  "shared_prefix_len": a.prompt_len // 2, "rate": a.rate,
                  "page_size": 8},
        "token_exact": True,  # asserted above, both runs identical
        "off": {"pages_allocated": occ_off["pages_allocated"],
                "ttft_s_p50": round(sum_off["ttft_s"]["p50"], 4),
                "wall_s": round(wall_off, 3)},
        "on": {"pages_allocated": occ_on["pages_allocated"],
               "pages_shared": occ_on["pages_shared"],
               "n_cow_forks": occ_on["n_cow_forks"],
               "prefix_hits": occ_on["prefix_hits"],
               "ttft_s_p50": round(sum_on["ttft_s"]["p50"], 4),
               "wall_s": round(wall_on, 3)},
        "tokens_skipped": occ_on["tokens_skipped"],
        "pages_alloc_ratio": pages_ratio,
        "ttft_hit_reduction": ttft_reduction,
    }
    assert occ_on["prefix_hits"] > 0 and occ_on["tokens_skipped"] > 0, \
        "shared-prefix trace produced no cache hits — the gate is vacuous"
    assert pages_ratio >= 1.3, \
        f"prefix cache cut pages allocated only {pages_ratio}x " \
        f"(need >= 1.3x on the shared-prefix trace)"
    return section


def bench_obs_overhead(args) -> dict:
    """BENCH_serve.json ``obs`` section (DESIGN.md §15.2, INFORMATIONAL —
    never regression-gated): the same seeded trace through the same
    continuous-batching deployment with tracing OFF then ON, comparing
    wall-clock ticks/s. Token equality IS asserted (the tracer may only
    observe, never steer — same contract test_obs gates), and so is the
    tick count; the overhead ratio itself is host-dependent, so it is
    only recorded for the CI log against §15.2's soft <5% expectation.
    Each leg takes the best of three runs after a shared warm-up so XLA
    compile time and scheduler jitter land outside the comparison."""
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import build_trace
    from repro.models import registry
    from repro.models.modules import Policy, RunConfig
    from repro.obs import trace as obs_trace
    from repro.serve import ServeConfig, ServeMetrics, build_deployment

    cfg = registry.get_config(PAGED_ARCH)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    sc = ServeConfig(slots=args.slots, max_len=args.prompt_len + args.gen,
                     prefill_chunk=args.prefill_chunk)

    def one(tracer):
        engine = build_deployment(cfg, mesh, run, sc,
                                  metrics=ServeMetrics())
        trace = build_trace(args.seed, args.requests, args.rate,
                            args.prompt_len, args.gen, cfg.vocab_size,
                            sc.sampling)
        with obs_trace.use(tracer):
            t0 = time.perf_counter()
            results = engine.run(trace)
            wall = time.perf_counter() - t0
        return results, engine.tick_count, wall

    one(None)  # warm-up: compile cache shared by every run below
    repeats = 3
    walls_off, walls_on = [], []
    res_off = ticks_off = None
    for _ in range(repeats):
        res_off, ticks_off, w = one(None)
        walls_off.append(w)
    tracer = None
    res_on = ticks_on = None
    for _ in range(repeats):
        tracer = obs_trace.Tracer()
        res_on, ticks_on, w = one(tracer)
        walls_on.append(w)

    assert res_on == res_off, \
        "tracing changed tokens — the tracer may only observe"
    assert ticks_on == ticks_off, \
        f"tracing changed the tick count ({ticks_off} -> {ticks_on})"
    assert tracer.events, "traced run emitted no events — nothing measured"
    wall_off, wall_on = min(walls_off), min(walls_on)
    overhead = round(wall_on / max(wall_off, 1e-9) - 1.0, 4)
    return {
        "arch": PAGED_ARCH,
        "informational": True,  # host-dependent; never regression-gated
        "token_exact": True,    # asserted above
        "ticks": ticks_off,
        "repeats": repeats,
        "untraced": {"wall_s": round(wall_off, 4),
                     "ticks_per_s": round(ticks_off / max(wall_off, 1e-9),
                                          2)},
        "traced": {"wall_s": round(wall_on, 4),
                   "ticks_per_s": round(ticks_on / max(wall_on, 1e-9), 2),
                   "n_events": len(tracer.events)},
        "overhead_ratio": overhead,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--paged", action="store_true",
                    help="run the paged-KV sweep (page_size x slots at "
                         "fixed simulated HBM)")
    ap.add_argument("--paged-requests", type=int, default=12)
    ap.add_argument("--paged-rate", type=float, default=1.5)
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregation section (simulated "
                         "goodput-ratio gate + measured role-split run)")
    ap.add_argument("--ep", action="store_true",
                    help="run the EP decode section (simulated "
                         "placement-ratio gate + measured EP-sharded run)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the elastic fleet section (simulated "
                         "elastic-vs-static goodput gate + measured "
                         "fleet run with a mid-trace group kill)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the chaos-resilience section (same fleet "
                         "trace fault-free vs under the standard fault "
                         "schedule; gates goodput_degraded_ratio)")
    ap.add_argument("--prefix", action="store_true",
                    help="run the prefix-cache section (shared-prefix "
                         "multi-tenant trace, cache OFF vs ON; gates "
                         "pages_alloc_ratio >= 1.3 and token-exactness)")
    ap.add_argument("--prefix-requests", type=int, default=10)
    ap.add_argument("--obs-overhead", action="store_true",
                    help="run the tracing-overhead section (same trace "
                         "with tracing OFF vs ON; informational ticks/s "
                         "ratio, asserts token- and tick-exactness)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    # fixed-trace knobs serve_arch reads beyond the CLI ones above
    args.mesh = "1x1"
    args.rate = 0.5
    args.seed = 0
    args.prefill_budget = None
    args.temperature = 0.0
    args.top_k = 0
    args.top_p = 1.0
    args.stream = False
    args.page_size = 16
    args.pool_pages = None
    args.prefill_pool_pages = None
    args.ep_size = 0
    args.ep_placement = "uniform"
    args.prefill_groups = "a40"
    args.decode_groups = "v100"
    args.fleet_elastic = False
    args.kill_group = None
    args.tenants = 0
    args.shared_prefix_len = None
    args.prefix_cache = False
    args.prefix_capacity = None
    args.fair = False
    run_paged = args.paged
    run_disagg = args.disagg
    run_ep = args.ep
    run_fleet = args.fleet
    run_chaos = args.chaos
    run_prefix = args.prefix
    run_obs = args.obs_overhead
    args.paged = False   # the base ARCHS runs stay on the dense engine
    args.disagg = False
    args.fleet = False
    args.chaos = None    # serve_arch reads this as the fault-spec string

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "trace": {"slots": args.slots, "requests": args.requests,
                  "prompt_len": args.prompt_len, "gen": args.gen,
                  "prefill_chunk": args.prefill_chunk, "rate": args.rate,
                  "seed": args.seed},
        "results": {arch: bench_arch(arch, args) for arch in ARCHS},
    }
    if run_paged:
        payload["paged"] = bench_paged_sweep(args)
        print(f"[bench_serve] paged: slot_ratio_best="
              f"{payload['paged']['slot_ratio_best']} "
              f"(config {payload['paged']['best_config']})")
    if run_disagg:
        payload["disagg"] = bench_disagg(args)
        print(f"[bench_serve] disagg: goodput_ratio_sim="
              f"{payload['disagg']['goodput_ratio_sim']} "
              f"(split {payload['disagg']['sim']['split']})")
    if run_ep:
        payload["ep"] = bench_ep(args)
        print(f"[bench_serve] ep: placement_ratio_sim="
              f"{payload['ep']['placement_ratio_sim']} "
              f"hbm_reduction={payload['ep']['hbm']['hbm_reduction']}")
    if run_fleet:
        payload["fleet"] = bench_fleet(args)
        print(f"[bench_serve] fleet: goodput_ratio_sim="
              f"{payload['fleet']['goodput_ratio_sim']} "
              f"(static roles "
              f"{payload['fleet']['sim']['best_static_roles']}, "
              f"{payload['fleet']['sim']['n_flips_elastic']} "
              f"elastic flips)")
    if run_prefix:
        payload["prefix"] = bench_prefix(args)
        p = payload["prefix"]
        print(f"[bench_serve] prefix: pages_alloc_ratio="
              f"{p['pages_alloc_ratio']} "
              f"(off {p['off']['pages_allocated']} -> on "
              f"{p['on']['pages_allocated']} pages, "
              f"{p['tokens_skipped']} lines skipped, "
              f"{p['on']['n_cow_forks']} COW forks, "
              f"ttft x{p['ttft_hit_reduction']})")
    if run_chaos:
        payload["chaos"] = bench_chaos(args)
        c = payload["chaos"]
        print(f"[bench_serve] chaos: goodput_degraded_ratio="
              f"{c['goodput_degraded_ratio']} "
              f"(clean {c['clean']['ticks']} ticks, degraded "
              f"{c['degraded']['ticks']} ticks, "
              f"{c['degraded']['faults_fired']} faults, "
              f"robustness {c['degraded']['robustness']})")
    if run_obs:
        payload["obs"] = bench_obs_overhead(args)
        o = payload["obs"]
        print(f"[bench_serve] obs: overhead_ratio={o['overhead_ratio']} "
              f"(untraced {o['untraced']['ticks_per_s']} ticks/s -> "
              f"traced {o['traced']['ticks_per_s']} ticks/s, "
              f"{o['traced']['n_events']} events over {o['ticks']} ticks)")
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
