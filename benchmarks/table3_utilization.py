"""Paper Table 3: GPU utilization (fraction of time on effective compute)
with ZP only and ZP+Asym-EA, vs DistEP — Mixtral-D1 on O1."""

from benchmarks.common import SETUPS, emit, global_batch_for
from repro.core import simulator as sim
from repro.core.planner import plan_zp_group
from repro.models import registry


def main():
    zp = SETUPS["O1"]
    cfg = registry.get_config("mixtral-d1")
    for s in (8192, 16384):
        gb = global_batch_for(s)
        plan = plan_zp_group(cfg, zp, gb, s, use_asym=False, n_chunks=1)
        with_asym = plan_zp_group(cfg, zp, gb, s, use_asym=True, n_chunks=1)
        dist = sim.distep_iter_time(cfg, zp, gb, s,
                                    min(zp.attn_class.link_bw,
                                        zp.exp_class.link_bw))
        for tag, res in [("zp_only", plan.predicted),
                         ("zp_asym", with_asym.predicted),
                         ("distep", dist)]:
            emit(f"table3/s{s}/{tag}", res.iter_time * 1e6,
                 f"attn_util={res.attn_util:.2f};"
                 f"exp_util={res.exp_util:.2f};"
                 f"attn_vs_distep={res.attn_util / max(dist.attn_util, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
