"""Paper Fig. 7: training throughput on the on-premise A40+V100 setups
(O1-O3) for the Table-2 Mixtral models: HeterMoE vs EP / DistEP /
EP (Ideal), via the discrete-event simulator + analytical profiler."""

from benchmarks.common import (PAPER_MODELS, SEQ_LENS, SETUPS, emit,
                               global_batch_for)
from repro.core import simulator as sim
from repro.core.planner import plan_zp_group
from repro.models import registry


def run_setup(setup_names, tag):
    for setup_name in setup_names:
        zp = SETUPS[setup_name]
        for model in PAPER_MODELS:
            cfg = registry.get_config(model)
            if cfg.n_experts % zp.N:
                continue  # EP divisibility (paper: experts % GPUs == 0)
            for s in SEQ_LENS:
                gb = global_batch_for(s)
                plan = plan_zp_group(cfg, zp, gb, s, n_chunks=1)  # paper-faithful: serialized dispatch
                tokens = gb * s
                th_hm = tokens / plan.predicted.iter_time
                # baselines
                t_ep = sim.ep_iter_time(cfg, zp, gb, s,
                                        min(zp.attn_class.link_bw,
                                            zp.exp_class.link_bw))
                th_ep = tokens / t_ep
                d = sim.distep_iter_time(cfg, zp, gb, s,
                                         min(zp.attn_class.link_bw,
                                             zp.exp_class.link_bw))
                th_dist = tokens / d.iter_time
                th_ideal = sim.ep_ideal_throughput(cfg, zp, gb, s)
                emit(f"fig7/{setup_name}/{model}/s{s}/hetermoe",
                     plan.predicted.iter_time * 1e6, f"tok_s={th_hm:.0f}")
                emit(f"fig7/{setup_name}/{model}/s{s}/ep",
                     t_ep * 1e6, f"tok_s={th_ep:.0f};"
                     f"hm_speedup={th_hm / th_ep:.2f}x")
                emit(f"fig7/{setup_name}/{model}/s{s}/distep",
                     d.iter_time * 1e6, f"tok_s={th_dist:.0f};"
                     f"hm_speedup={th_hm / th_dist:.2f}x")
                emit(f"fig7/{setup_name}/{model}/s{s}/ep_ideal",
                     tokens / th_ideal * 1e6, f"tok_s={th_ideal:.0f};"
                     f"hm_speedup={th_hm / th_ideal:.2f}x")


def main():
    run_setup(["O1", "O2", "O3"], "fig7")


if __name__ == "__main__":
    main()
