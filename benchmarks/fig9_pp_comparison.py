"""Paper Fig. 9: HeterMoE's zebra parallelism vs heterogeneity-aware
pipeline parallelism (Metis/FlashFlex-style layer balancing)."""

from benchmarks.common import SEQ_LENS, SETUPS, emit, global_batch_for
from repro.core import simulator as sim
from repro.core.planner import plan_zp_group
from repro.models import registry


def main():
    for setup_name in ("O1", "O2"):
        zp = SETUPS[setup_name]
        for model in ("mixtral-w1", "mixtral-d1"):
            cfg = registry.get_config(model)
            if cfg.n_experts % zp.N:
                continue
            for s in SEQ_LENS:
                gb = global_batch_for(s)
                plan = plan_zp_group(cfg, zp, gb, s, n_chunks=1)  # paper-faithful: serialized dispatch
                th_hm = gb * s / plan.predicted.iter_time
                t_pp = sim.pp_iter_time(cfg, zp, gb, s)
                th_pp = gb * s / t_pp
                emit(f"fig9/{setup_name}/{model}/s{s}/hetermoe",
                     plan.predicted.iter_time * 1e6, f"tok_s={th_hm:.0f}")
                emit(f"fig9/{setup_name}/{model}/s{s}/pp", t_pp * 1e6,
                     f"tok_s={th_pp:.0f};hm_speedup={th_hm / th_pp:.2f}x")


if __name__ == "__main__":
    main()
