"""Paper Fig. 8: throughput on the AWS setups (L40S + T4, C1/C2)."""

from benchmarks.fig7_throughput_onprem import run_setup


def main():
    run_setup(["C1", "C2"], "fig8")


if __name__ == "__main__":
    main()
