"""Shared benchmark plumbing: cluster setups from the paper's Table 1,
model list from Table 2, CSV emission."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

from repro.core import hardware as HW
from repro.core.profiler import ZPGroupShape
from repro.models import registry

# Paper Table 1 cluster setups.
SETUPS: Dict[str, ZPGroupShape] = {
    "O1": ZPGroupShape(M=6, N=6, attn_class=HW.A40, exp_class=HW.V100),
    "O2": ZPGroupShape(M=4, N=8, attn_class=HW.A40, exp_class=HW.V100),
    "O3": ZPGroupShape(M=6, N=3, attn_class=HW.A40, exp_class=HW.V100),
    "C1": ZPGroupShape(M=2, N=6, attn_class=HW.L40S, exp_class=HW.T4),
    "C2": ZPGroupShape(M=2, N=8, attn_class=HW.L40S, exp_class=HW.T4),
}

# Paper Table 2 models.
PAPER_MODELS = ["mixtral-w1", "mixtral-w2", "mixtral-d1", "mixtral-d2",
                "mixtral-d3"]

SEQ_LENS = [4096, 8192, 16384, 24576, 32768]


def global_batch_for(seq_len: int, tokens_per_iter: int = 2 ** 22) -> int:
    """Paper: 'global batch size to the maximum allowed by GPU memory' —
    we hold tokens/iteration constant (~4M) across sequence lengths."""
    return max(tokens_per_iter // seq_len, 2)


ROWS: List[dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """Required CSV row format: name,us_per_call,derived."""
    ROWS.append({"name": name, "us": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        out = fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / iters * 1e6
