"""MoE expert-FFN path shootout — tracks the single-pack fused pipeline.

Compares, at a Mixtral (paper Table 2) layer shape, the four ways this
repo can run the grouped GLU expert FFN over expert-sorted rows:

  dense       every token through every expert (apply_moe's exact mode,
              O(E) compute) — the correctness baseline
  ragged      3x lax.ragged_dot (the pre-fused gather-mode path)
  gmm_percall 3x ops.gmm — Pallas grouped GEMM that re-packs inside every
              call (interpret-mode Python execution off-TPU, so off-TPU
              it is timing the interpreter, not the pipeline; opt-in)
  fused       ops.moe_ffn — pack once, GLU-fused grouped GEMM, packed
              VJP (Pallas on TPU, XLA tile-gather fallback elsewhere)

Emits BENCH_moe_ffn.json (repo root by default) so the speedup is tracked
across PRs. The regression gate compares fused vs ragged (both pure-XLA
off TPU); interpret-mode timings are excluded from the gate.

Usage:
    PYTHONPATH=src python benchmarks/bench_moe_ffn.py [--paper]
        [--tokens N] [--iters K] [--with-interpret] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

GATE_SPEEDUP = 1.3


def timed(fn, args, iters):
    out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.map(lambda a: a.block_until_ready(), out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def routed_group_sizes(key, M, E):
    """Realistic mildly-imbalanced router assignment summing to M."""
    logits = jax.random.normal(key, (E,)) * 0.3
    p = jax.nn.softmax(logits)
    sizes = jnp.floor(p * M).astype(jnp.int32)
    sizes = sizes.at[0].add(M - jnp.sum(sizes))
    return sizes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="full mixtral-w1 layer shape (slow off-TPU)")
    ap.add_argument("--tokens", type=int, default=2048,
                    help="tokens per step (rows = tokens * top_k)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--with-interpret", action="store_true",
                    help="also time the per-call Pallas gmm path off-TPU")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    top_k, E = 2, 12  # mixtral-w1 routing
    if args.paper:
        d, f = 2048, 7168  # mixtral-w1 (Table 2)
        shape_name = "mixtral-w1"
    else:
        d, f = 512, 1792  # mixtral-w1 / 4 — same ratios, CI-sized
        shape_name = "mixtral-w1/4"
    M = args.tokens * top_k

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (M, d), jnp.float32) * 0.5
    wg = jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.02
    wu = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.02
    wo = jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.02
    gs = routed_group_sizes(ks[4], M, E)
    on_tpu = jax.default_backend() == "tpu"

    def dense(x, wg, wu, wo):
        # every token (M/k of them) through every expert
        xt = x[::top_k]
        g = jax.nn.silu(jnp.einsum("td,edf->tef", xt, wg))
        u = jnp.einsum("td,edf->tef", xt, wu)
        return jnp.einsum("tef,efd->ted", g * u, wo)

    def ragged(x, wg, wu, wo):
        g = jax.nn.silu(jax.lax.ragged_dot(x, wg, gs))
        u = jax.lax.ragged_dot(x, wu, gs)
        return jax.lax.ragged_dot(g * u, wo, gs)

    def gmm_percall(x, wg, wu, wo):
        g = jax.nn.silu(ops.gmm(x, wg, gs))
        u = ops.gmm(x, wu, gs)
        return ops.gmm(g * u, wo, gs)

    def fused(x, wg, wu, wo):
        return ops.moe_ffn(x, wg, wu, wo, gs, small_m=False)

    paths = {"dense": dense, "ragged": ragged, "fused": fused}
    if on_tpu or args.with_interpret:
        paths["gmm_percall"] = gmm_percall

    results = {}
    for name, fn in paths.items():
        fwd = jax.jit(fn)
        grad = jax.jit(jax.grad(
            lambda *a, _f=fn: jnp.sum(_f(*a) ** 2), argnums=(0, 1, 2, 3)))
        fwd_ms = timed(fwd, (xs, wg, wu, wo), args.iters)
        grad_ms = timed(grad, (xs, wg, wu, wo), args.iters)
        results[name] = {"fwd_ms": round(fwd_ms, 3),
                         "grad_ms": round(grad_ms, 3)}
        print(f"{name:12s} fwd {fwd_ms:9.2f} ms   fwd+bwd {grad_ms:9.2f} ms")

    gate = {
        "baseline": "ragged",
        "threshold": GATE_SPEEDUP,
        "fused_vs_ragged_fwd": round(
            results["ragged"]["fwd_ms"] / results["fused"]["fwd_ms"], 3),
        "fused_vs_ragged_grad": round(
            results["ragged"]["grad_ms"] / results["fused"]["grad_ms"], 3),
    }
    gate["pass"] = (gate["fused_vs_ragged_fwd"] >= GATE_SPEEDUP
                    and gate["fused_vs_ragged_grad"] >= GATE_SPEEDUP)
    print(f"gate: fused vs ragged {gate['fused_vs_ragged_fwd']}x fwd, "
          f"{gate['fused_vs_ragged_grad']}x fwd+bwd "
          f"({'PASS' if gate['pass'] else 'FAIL'} at {GATE_SPEEDUP}x)")

    # --- small-M (decode-shape) crossover: group-dense vs packed ----------
    # ROADMAP follow-up: at small M the packed pipeline's ~E*block_m pad
    # rows dominate, so moe_ffn auto-routes to the group-dense fallback
    # when M*(E-1) <= E*block_m (break-even near block_m rows). Record
    # both sides at the requested token count AND at a true decode shape
    # (16 tokens ~ a slot batch), bracketing the crossover.
    small_m = {"auto_rule": "M*(G-1) <= G*block_m", "block_m": 128,
               "points": []}
    for sm_tokens in sorted({min(args.tokens, 128), min(args.tokens, 16)}):
        Ms = sm_tokens * top_k
        xs_s = xs[:Ms]
        gs_s = routed_group_sizes(ks[4], Ms, E)

        def sm_path(small_flag, _gs=gs_s):
            return jax.jit(lambda x, wg, wu, wo: ops.moe_ffn(
                x, wg, wu, wo, _gs, small_m=small_flag))

        pt = {"rows": Ms,
              "auto_routes_to": "group_dense"
              if Ms * (E - 1) <= E * 128 else "fused"}
        for name, fn in [("group_dense", sm_path(True)),
                         ("fused_packed", sm_path(False))]:
            ms = timed(fn, (xs_s, wg, wu, wo), args.iters)
            pt[f"{name}_fwd_ms"] = round(ms, 3)
            print(f"small-M ({Ms:4d} rows) {name:12s} fwd {ms:9.2f} ms")
        pt["group_dense_speedup"] = round(
            pt["fused_packed_fwd_ms"] / pt["group_dense_fwd_ms"], 3)
        print(f"small-M ({Ms:4d} rows): group-dense "
              f"{pt['group_dense_speedup']}x vs packed "
              f"(auto -> {pt['auto_routes_to']})")
        small_m["points"].append(pt)

    # --- block-size autotuning sweep for the fused GLU grouped GEMM ------
    # Candidates are (block_m, block_n) pairs that fit the per-core VMEM
    # budget given gmm_glu_tiled's working set (lhs/gate/up/out tiles
    # double-buffered + two f32 accumulators; kernels/gmm.glu_vmem_bytes).
    # Off-TPU the XLA tile-gather fallback executes the same packed domain,
    # where block_n does not bind (no rhs tiling) — the sweep still ranks
    # block_m, and the VMEM feasibility set is what TPU runs consult.
    from repro.kernels import gmm as gmm_mod
    autotune = {"vmem_budget_bytes": gmm_mod.VMEM_BUDGET_BYTES,
                "block_k": 128,
                "note": "block_n binds only on the Mosaic (TPU) path",
                "candidates": []}
    for bm, bn in gmm_mod.glu_block_candidates():
        fn = jax.jit(lambda x, wg, wu, wo, _bm=bm, _bn=bn: ops.moe_ffn(
            x, wg, wu, wo, gs, small_m=False, block_m=_bm, block_n=_bn))
        ms = timed(fn, (xs, wg, wu, wo), args.iters)
        vb = gmm_mod.glu_vmem_bytes(bm, 128, bn)
        autotune["candidates"].append(
            {"block_m": bm, "block_n": bn, "fwd_ms": round(ms, 3),
             "vmem_bytes": vb})
        print(f"autotune bm={bm:4d} bn={bn:4d} fwd {ms:9.2f} ms "
              f"(vmem {vb/2**20:.1f} MiB)")
    chosen = min(autotune["candidates"], key=lambda c: c["fwd_ms"])
    autotune["chosen"] = {"block_m": chosen["block_m"],
                          "block_n": chosen["block_n"],
                          "fwd_ms": chosen["fwd_ms"]}
    print(f"autotune chosen: block_m={chosen['block_m']} "
          f"block_n={chosen['block_n']} ({chosen['fwd_ms']} ms)")

    payload = {
        "bench": "moe_ffn",
        "shape": {"name": shape_name, "d_model": d, "d_ff": f, "experts": E,
                  "top_k": top_k, "rows": M},
        "backend": jax.default_backend(),
        "iters": args.iters,
        "results": results,
        "small_m": small_m,
        "autotune": autotune,
        "gate": gate,
    }
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parents[1] / "BENCH_moe_ffn.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return 0 if gate["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
