"""Paper Fig. 10: impact of the A40:V100 ratio in a ZP group. M fixed at 4;
experts scale with N so EP divisibility holds; compares against EP (Ideal).
Asym-EA activates only where M|N or N|M (paper §4.2)."""

import dataclasses

from benchmarks.common import emit, global_batch_for
from repro.core import hardware as HW, simulator as sim
from repro.core.planner import plan_zp_group
from repro.core.profiler import ZPGroupShape
from repro.models import registry


def main():
    base = registry.get_config("mixtral-d1")
    for s in (4096, 12288, 20480, 32768):
        gb = global_batch_for(s)
        for N in (2, 3, 4, 5, 6, 7, 8):
            cfg = dataclasses.replace(base, n_experts=3 * N)
            zp = ZPGroupShape(M=4, N=N, attn_class=HW.A40,
                              exp_class=HW.V100)
            plan = plan_zp_group(cfg, zp, gb, s, n_chunks=1)  # paper-faithful: serialized dispatch
            th = gb * s / plan.predicted.iter_time
            th_ideal = sim.ep_ideal_throughput(cfg, zp, gb, s)
            emit(f"fig10/s{s}/ratio4to{N}",
                 plan.predicted.iter_time * 1e6,
                 f"tok_s={th:.0f};vs_ideal={th / th_ideal:.2f}x;"
                 f"asym_offload={sum(plan.offload)}")


if __name__ == "__main__":
    main()
