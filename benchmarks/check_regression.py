"""Benchmark regression gate: compare a fresh smoke-run BENCH_*.json
against the committed JSON and fail on >25% regression of the SIMULATED
metrics. Measured (wall-clock) metrics are host-dependent — CI runners
vary 2-3x — so they are printed as informational deltas only; the
simulated metrics are deterministic functions of the trace/model and gate
hard.

Gated metrics (higher is better):
  serve: paged.slot_ratio_best           (slots at fixed HBM vs reservation)
  serve: disagg.goodput_ratio_sim        (simulated disagg vs unified goodput)
  serve: ep.placement_ratio_sim          (simulated uniform vs planned EP
                                          placement makespan on a Zipf trace)
  serve: fleet.goodput_ratio_sim         (simulated elastic fleet vs best
                                          static split, goodput under SLO)
  serve: chaos.goodput_degraded_ratio    (simulated goodput under the
                                          standard fault schedule vs
                                          fault-free, tokens per tick)
  serve: prefix.pages_alloc_ratio        (pages allocated cache-off vs
                                          cache-on, shared-prefix trace)
  serve: prefix.tokens_skipped           (prefill lines served from cache
                                          on the fixed trace)
  zebra: gate.speedup                    (simulated overlapped vs serialized)

Usage:
    python benchmarks/check_regression.py --bench serve \
        --fresh /tmp/BENCH_serve.json [--committed BENCH_serve.json]
    python benchmarks/check_regression.py --bench zebra \
        --fresh /tmp/BENCH_zebra.json
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

# bench -> (committed file, simulated gate keys, informational keys).
# Keys are dotted paths; higher is better for every gated key.
BENCHES = {
    "serve": {
        "file": "BENCH_serve.json",
        "simulated": ["paged.slot_ratio_best",
                      "disagg.goodput_ratio_sim",
                      "ep.placement_ratio_sim",
                      "fleet.goodput_ratio_sim",
                      "chaos.goodput_degraded_ratio",
                      "prefix.pages_alloc_ratio",
                      "prefix.tokens_skipped"],
        "measured": ["results.qwen3-moe-30b-a3b.tokens_per_s",
                     "results.llama3.2-3b.tokens_per_s",
                     "disagg.measured.tokens_per_s",
                     "ep.measured.tokens_per_s",
                     "fleet.measured.tokens_per_s",
                     "prefix.ttft_hit_reduction"],
    },
    "zebra": {
        "file": "BENCH_zebra.json",
        "simulated": ["gate.speedup"],
        "measured": ["measured.points.1.step_ms",
                     "measured.points.2.step_ms"],
    },
}


def lookup(tree, dotted: str):
    """Resolve a dotted path, longest-key-first so keys containing dots
    (arch names like "llama3.2-3b") resolve too."""
    node = tree
    while dotted:
        if not isinstance(node, dict):
            return None
        for k in sorted(node, key=len, reverse=True):
            if dotted == k:
                return node[k]
            if dotted.startswith(k + "."):
                node, dotted = node[k], dotted[len(k) + 1:]
                break
        else:
            return None
    return node


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", choices=sorted(BENCHES), required=True)
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--committed", default=None,
                    help="baseline JSON (default: the repo-committed one)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional regression (default 0.25)")
    ap.add_argument("--sections", default=None,
                    help="comma-separated top-level section filter (e.g. "
                         "'paged' or 'disagg'): gate only keys under these "
                         "sections, so a CI job that benches one slice is "
                         "not failed for sections it deliberately did not "
                         "produce. Default: gate every key (a full bench "
                         "run must carry every section).")
    args = ap.parse_args(argv)

    spec = BENCHES[args.bench]
    if args.sections:
        keep = tuple(s.strip() for s in args.sections.split(","))
        spec = dict(spec)
        for group in ("simulated", "measured"):
            spec[group] = [k for k in spec[group]
                           if k.split(".")[0] in keep]
        if not spec["simulated"]:
            print(f"[gate] --sections {args.sections} matches no gated "
                  f"metric for bench '{args.bench}'", file=sys.stderr)
            return 2
    committed_path = pathlib.Path(args.committed) if args.committed \
        else REPO / spec["file"]
    fresh = json.loads(pathlib.Path(args.fresh).read_text())
    committed = json.loads(committed_path.read_text())

    failures = []
    for key in spec["simulated"]:
        new, old = lookup(fresh, key), lookup(committed, key)
        if old is None:
            print(f"[gate] {args.bench}.{key}: no committed baseline "
                  f"({committed_path.name}) — recording only, new={new}")
            continue
        if new is None:
            failures.append(f"{key}: missing from fresh run (baseline {old})")
            continue
        floor = old * (1.0 - args.threshold)
        status = "OK" if new >= floor else "REGRESSION"
        print(f"[gate] {args.bench}.{key}: committed={old} fresh={new} "
              f"floor={floor:.4f} -> {status}")
        if new < floor:
            failures.append(f"{key}: {new} < {floor:.4f} "
                            f"(committed {old}, -{args.threshold:.0%} floor)")

    for key in spec["measured"]:
        new, old = lookup(fresh, key), lookup(committed, key)
        if new is not None and old not in (None, 0):
            print(f"[info] {args.bench}.{key}: committed={old} fresh={new} "
                  f"({new / old:.0%} of baseline; informational)")

    if failures:
        print(f"[gate] FAIL ({args.bench}):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"[gate] PASS ({args.bench})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
