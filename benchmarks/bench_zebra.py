"""Zebra overlapped-dispatch benchmark — step time vs n_chunks.

Tracks the chunked, double-buffered dispatch pipeline (DESIGN.md §8): the
[E, C, d] dispatch buffer is split into n_chunks capacity slices so the
all-to-all of chunk k+1 rides under the expert GEMM of chunk k.

Two sections land in BENCH_zebra.json:

  * simulated (the regression gate): the discrete-event simulator — the
    paper's own throughput methodology (§6.4.1 fn.2) and where this repo's
    throughput claims live (zebra_mpmd docstring) — prices the canonical
    Theorem-1 schedule at n_chunks ∈ {1, 2, 4} on the benchmark config
    (mixtral-w1 on the paper's A40+V100 ZP group). Overlapped dispatch
    (n_chunks >= 2) must be STRICTLY faster than serialized (n_chunks=1).
  * measured (informational, NOISY): wall-clock per-step fwd+bwd time of
    the SPMD alltoall engine on emulated devices. On a CPU container every
    emulated device shares one core, so overlap CANNOT materialize in
    wall-clock; what this records is the program-count overhead floor of
    chunking on an emulated backend (numbers vary run to run by 2-3x under
    CPU thread-scheduling noise). It is not a throughput claim — those
    live in the simulated section, per the paper's methodology.

Usage:
    PYTHONPATH=src python benchmarks/bench_zebra.py [--smoke]
        [--no-measure] [--iters K] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import time

_flags = os.environ.get("XLA_FLAGS", "")  # before jax import: emulated group
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

CHUNKS = (1, 2, 4)


def simulated_sweep(smoke: bool):
    from repro.core import hardware as HW
    from repro.core import planner
    from repro.core.profiler import ZPGroupShape

    from repro.models import registry
    cfg = registry.get_config("mixtral-w1")
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    global_batch, seq_len = (8, 1024) if smoke else (16, 4096)
    out = {"config": "mixtral-w1", "zp": {"M": zp.M, "N": zp.N,
                                          "attn_class": zp.attn_class.name,
                                          "exp_class": zp.exp_class.name},
           "global_batch": global_batch, "seq_len": seq_len, "points": {}}
    for q in CHUNKS:
        plan = planner.plan_zp_group(cfg, zp, global_batch, seq_len,
                                     n_chunks=q)
        out["points"][str(q)] = {
            "iter_time_ms": round(plan.predicted.iter_time * 1e3, 4),
            "attn_util": round(plan.predicted.attn_util, 4),
            "exp_util": round(plan.predicted.exp_util, 4),
            "R": plan.R,
            "offload_total": sum(plan.offload),
        }
        print(f"sim n_chunks={q}: iter {plan.predicted.iter_time*1e3:9.3f} ms"
              f"  attn_util {plan.predicted.attn_util:.3f}"
              f"  exp_util {plan.predicted.exp_util:.3f}"
              f"  offload {sum(plan.offload)}")
    return out


def measured_sweep(iters: int):
    """Wall-clock fwd+bwd of the SPMD alltoall MoE layer per n_chunks."""
    import dataclasses

    from jax.sharding import Mesh

    from repro.core import zebra_spmd as Z
    from repro.models import modules, registry
    from repro.models.modules import Policy, RunConfig
    from repro.pytree import split_params

    run = RunConfig(policy=Policy(compute_dtype=jnp.float32))
    cfg = registry.smoke_config(registry.get_config("mixtral-w1"))
    cfg = dataclasses.replace(cfg, capacity_factor=2.0)
    key = jax.random.PRNGKey(0)
    ffn, _ = split_params(modules.init_moe(key, cfg))
    devs = jax.devices()
    if len(devs) < 8:  # someone forced a smaller emulated pool
        return {"skipped": f"needs 8 devices, have {len(devs)}"}
    mesh = Mesh(np.array(devs[:8]).reshape(2, 4), ("data", "model"))
    x = jax.random.normal(key, (512, cfg.d_model), jnp.float32) * 0.3
    out = {"config": "mixtral-w1-smoke", "tokens": int(x.shape[0]),
           "note": ("emulated single-core devices: no wall-clock overlap "
                    "possible; run-to-run noise 2-3x; see module docstring"),
           "points": {}}
    for q in CHUNKS:
        zcfg = Z.ZebraConfig(mode="alltoall", capacity_factor=2.0,
                             batch_axes=("data", "model"), n_chunks=q)
        with mesh:
            moe_fn = Z.make_ep_moe(mesh, cfg, run, zcfg)
            step = jax.jit(jax.grad(
                lambda f, xx: jnp.sum(moe_fn(f, xx)[0] ** 2)))
            g = step(ffn, x)
            jax.tree.map(lambda a: a.block_until_ready(), g)
            t0 = time.perf_counter()
            for _ in range(iters):
                g = step(ffn, x)
                jax.tree.map(lambda a: a.block_until_ready(), g)
            ms = (time.perf_counter() - t0) / iters * 1e3
        out["points"][str(q)] = {"step_ms": round(ms, 3)}
        print(f"measured n_chunks={q}: {ms:9.2f} ms/step (emulated devices)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes + measured engine smoke")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip the wall-clock engine sweep")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    simulated = simulated_sweep(args.smoke)
    serialized = simulated["points"]["1"]["iter_time_ms"]
    overlapped = min(simulated["points"][str(q)]["iter_time_ms"]
                     for q in CHUNKS if q > 1)
    gate = {
        "metric": "simulated iter_time_ms",
        "serialized_n_chunks_1": serialized,
        "best_overlapped": overlapped,
        "speedup": round(serialized / overlapped, 4),
        "pass": overlapped < serialized,
    }
    print(f"gate: overlapped {overlapped} ms vs serialized {serialized} ms "
          f"({gate['speedup']}x, {'PASS' if gate['pass'] else 'FAIL'})")

    payload = {"bench": "zebra_overlap", "backend": jax.default_backend(),
               "n_chunks_sweep": list(CHUNKS), "simulated": simulated,
               "gate": gate}
    if not args.no_measure:
        payload["measured"] = measured_sweep(args.iters)

    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parents[1] / "BENCH_zebra.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    return 0 if gate["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
