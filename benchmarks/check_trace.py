#!/usr/bin/env python
"""CI validator for --trace-out artifacts (DESIGN.md §15.5).

Checks the exact export shape `obs/export.py` promises: a
Perfetto-loadable Chrome trace-event object with named processes and
threads, positive-duration X events, flow events carrying string ids,
plus the two repo-specific keys — `reproCounters` (registry snapshot)
and `reproIdle` (idle attribution, whose tick-track buckets must sum to
ticks − busy EXACTLY and must be NON-EMPTY: a trace with no idle report
means the driver exported before attribution ran).

    python benchmarks/check_trace.py /tmp/trace.json \
        --expect-track g0 --expect-track chaos --expect-span prefill

Exits non-zero with one line per violation.
"""

import argparse
import json
import sys

IDLE_BUCKETS = ("queue-starved", "pool-OOM", "a2a-exposed", "transfer-wait",
                "drain", "fault-stall")


def check(obj, expect_tracks=(), expect_spans=(), min_events=1):
    errs = []
    ev = obj.get("traceEvents")
    if not isinstance(ev, list) or len(ev) < min_events:
        return [f"traceEvents missing or < {min_events} events"]

    tracks = set()
    span_names = set()
    procs = set()
    for e in ev:
        ph = e.get("ph")
        if ph is None or "pid" not in e:
            errs.append(f"event without ph/pid: {e}")
            continue
        if ph == "M":
            if e["name"] == "thread_name":
                tracks.add(e["args"]["name"])
            elif e["name"] == "process_name":
                procs.add(e["args"]["name"])
        elif ph == "X":
            span_names.add(e["name"])
            if not (isinstance(e.get("dur"), (int, float)) and e["dur"] > 0):
                errs.append(f"X event with non-positive dur: {e['name']}")
            if "ts" not in e:
                errs.append(f"X event without ts: {e['name']}")
        elif ph in ("s", "t", "f"):
            if not isinstance(e.get("id"), str):
                errs.append(f"flow event with non-string id: {e}")
    if not procs:
        errs.append("no process_name metadata")
    if not tracks:
        errs.append("no thread_name metadata")
    for t in expect_tracks:
        if t not in tracks:
            errs.append(f"expected track {t!r} missing (have {sorted(tracks)})")
    for s in expect_spans:
        if s not in span_names:
            errs.append(f"expected span {s!r} missing "
                        f"(have {sorted(span_names)})")

    if not isinstance(obj.get("reproCounters"), dict):
        errs.append("reproCounters missing or not a dict")
    idle = obj.get("reproIdle")
    if not isinstance(idle, dict) or not idle:
        errs.append("reproIdle missing or EMPTY — idle attribution never ran")
        return errs
    for track, r in idle.items():
        if r.get("kind") == "tick":
            if set(r["buckets"]) - set(IDLE_BUCKETS):
                errs.append(f"{track}: unknown idle bucket(s) "
                            f"{set(r['buckets']) - set(IDLE_BUCKETS)}")
            if sum(r["buckets"].values()) != r["idle"] \
                    or r["idle"] != r["ticks"] - r["busy"]:
                errs.append(f"{track}: idle identity broken — "
                            f"sum(buckets)={sum(r['buckets'].values())} "
                            f"idle={r['idle']} ticks={r['ticks']} "
                            f"busy={r['busy']}")
        elif r.get("kind") == "time":
            if r["busy_s"] < 0 or r["idle_s"] < -1e-9:
                errs.append(f"{track}: negative time accounting")
        else:
            errs.append(f"{track}: unknown report kind {r.get('kind')!r}")
    return errs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="path to a --trace-out JSON artifact")
    ap.add_argument("--expect-track", action="append", default=[],
                    help="thread name that must exist (repeatable)")
    ap.add_argument("--expect-span", action="append", default=[],
                    help="X-event name that must exist (repeatable)")
    ap.add_argument("--min-events", type=int, default=1)
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        obj = json.load(f)
    errs = check(obj, expect_tracks=args.expect_track,
                 expect_spans=args.expect_span, min_events=args.min_events)
    if errs:
        for e in errs:
            print(f"[check_trace] FAIL: {e}", file=sys.stderr)
        return 1
    idle = obj["reproIdle"]
    print(f"[check_trace] OK: {len(obj['traceEvents'])} events, "
          f"{len(idle)} idle-attributed tracks "
          f"({', '.join(sorted(idle))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
