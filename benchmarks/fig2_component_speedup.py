"""Paper Fig. 2: newer-over-older GPU speed-up on attention vs expert
modules (Mixtral-8x7B setting), from the calibrated hardware model."""

from benchmarks.common import emit
from repro.core import hardware as HW, profiler as PF
from repro.models.config import LayerSpec, ModelConfig

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, d_ff_expert=14336,
    vocab_size=32000, pattern=(LayerSpec(ffn="moe"),), n_experts=8, top_k=2)


def main():
    for new, old, tag in [(HW.A40, HW.V100, "a40_over_v100"),
                          (HW.L40S, HW.T4, "l40s_over_t4")]:
        for s in (4096, 8192, 16384, 32768, 65536):
            ta_new = PF.attention_block_time(MIXTRAL_8X7B, s, s, new) * 3
            ta_old = PF.attention_block_time(MIXTRAL_8X7B, s, s, old) * 3
            te_new = PF.expert_ffn_time(MIXTRAL_8X7B, s, new) * 3
            te_old = PF.expert_ffn_time(MIXTRAL_8X7B, s, old) * 3
            emit(f"fig2/{tag}/attn/s{s}", ta_old * 1e6,
                 f"speedup={ta_old / ta_new:.2f}x")
            emit(f"fig2/{tag}/expert/s{s}", te_old * 1e6,
                 f"speedup={te_old / te_new:.2f}x")


if __name__ == "__main__":
    main()
